//! Bit-packed runtime weight format + fused dequantize-matmul kernels.
//!
//! [`QuantizedTensor`] is the serving-time sibling of
//! [`crate::quant::QuantizedLinear`]: codes stay **bit-packed** in memory
//! (the whole point of low-bit deployment) with the per-group RTN scales
//! `s`, shifts `z`, and the SINQ second-axis column scales `t` resident
//! alongside. The kernels unpack codes block-wise into a cache-sized tile
//! and multiply in the same pass — the CPU analogue of the Pallas
//! `dequant_matmul` kernel at L1:
//!
//! * [`QuantizedTensor::dequant_matmul`] — `y = x · Wᵀ` for a batch of
//!   activations; W rows are dequantized once per 8-row tile and shared
//!   across every activation row, parallelized over the thread pool.
//! * [`QuantizedTensor::dequant_matvec`] — the decode fast path: never
//!   materializes dequantized weights at all. With `x·t` folded once into
//!   the input and per-group partial sums carrying the shift term, each
//!   output element is `Σ_g s_g·(q·x t) + s_g z_g Σ(x t)` straight from the
//!   packed codes.
//! * [`QuantizedTensor::dequant_matmul_shared`] — the continuous-batching
//!   decode kernel: same code-space arithmetic as `dequant_matvec`, but each
//!   weight row is unpacked **once per step and shared across every live
//!   sequence's activation row**, so batched decode is bit-identical to
//!   single-sequence decode while amortizing the unpack `batch`×.
//!
//! All three entry points drive one cache-blocked tile iterator
//! ([`QuantizedTensor::tiled_rows`]) and dispatch their inner loops —
//! unpack, LUT level decode, and the dot reduction — through
//! [`crate::backend::simd`], which selects explicit AVX2/NEON kernels at
//! runtime with the scalar code as portable fallback and parity oracle.
//! Unpacked codes and decoded levels are bit-identical across kernels;
//! both decode entry points share the same dispatched dot per row, so
//! batched greedy decode always reproduces single-sequence decode exactly.

use crate::backend::simd::{self, Isa, KernelScratch};
use crate::fmt::pack;
use crate::quant::QuantizedLinear;
use crate::tensor::Matrix;
use crate::util::threadpool;

/// Output rows dequantized per tile in the fused matmuls; 8 rows × ≤4 KiB
/// of f32 per row keeps the tile L1/L2-resident.
const ROW_BLOCK: usize = 8;

/// Below this many multiply-accumulates the kernel stays single-threaded
/// (even a persistent-pool hand-off costs more than the work).
const PARALLEL_THRESHOLD: usize = 1 << 18;

/// A linear layer kept in its packed on-disk representation at runtime.
///
/// Dequantization contract (identical to `QuantizedLinear::dequantize`):
/// `W[i][j] = s[i][j/g] * (decode(Q[i][j]) + z[i][j/g]) * t[j]`.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// Output features.
    pub rows: usize,
    /// Input features.
    pub cols: usize,
    /// Group size along the input dimension.
    pub group_size: usize,
    /// Code width in bits (2..=8).
    pub bits: u32,
    /// Packed bytes per row (rows are packed independently so any row can
    /// be addressed without decoding its predecessors).
    row_stride: usize,
    /// `rows * row_stride` packed code bytes.
    packed: Vec<u8>,
    /// Per (row, group) scale `s`.
    pub scales: Matrix,
    /// Per (row, group) shift `z` (uniform asymmetric grids only).
    pub shifts: Option<Matrix>,
    /// Per-column SINQ scale `t`.
    pub col_scale: Option<Vec<f32>>,
    /// 256-entry code → value decode table (covers uniform and level-table
    /// grids with one lookup; entries past the grid size are zero).
    lut: Vec<f32>,
}

impl QuantizedTensor {
    /// Convert a quantizer-zoo layer into the packed runtime format.
    ///
    /// Returns `None` for representations the fused kernels cannot execute
    /// directly (Hadamard-rotated storage, 2-D pair codebooks) — callers
    /// fall back to a dense dequantized copy for those.
    pub fn from_linear(q: &QuantizedLinear) -> Option<QuantizedTensor> {
        if q.hadamard || q.hadamard_out || q.pair_codebook.is_some() {
            return None;
        }
        let bits = q.grid.bits();
        if !(2..=8).contains(&bits) {
            return None;
        }
        if q.codes.len() != q.rows * q.cols {
            return None;
        }
        let row_stride = pack::packed_len(q.cols, bits);
        let mut packed = Vec::with_capacity(q.rows * row_stride);
        for i in 0..q.rows {
            packed.extend_from_slice(&pack::pack(&q.codes[i * q.cols..(i + 1) * q.cols], bits));
        }
        let mut lut = vec![0.0f32; 256];
        for (c, slot) in lut.iter_mut().enumerate().take(q.grid.size().min(256)) {
            *slot = q.grid.decode(c as u8);
        }
        Some(QuantizedTensor {
            rows: q.rows,
            cols: q.cols,
            group_size: q.group_size,
            bits,
            row_stride,
            packed,
            scales: q.scales.clone(),
            shifts: q.shifts.clone(),
            col_scale: q.col_scale.clone(),
            lut,
        })
    }

    /// Number of input-dimension groups.
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Resident bytes of the packed code payload (what full dequantization
    /// would inflate by `32/bits`×).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len()
    }

    /// Packed code bytes of row `i`.
    fn row_bytes(&self, i: usize) -> &[u8] {
        &self.packed[i * self.row_stride..(i + 1) * self.row_stride]
    }

    /// Dequantize row `i` into `out` (`out.len() == cols`), using
    /// `codes_buf` (`len == cols`) as unpack scratch. Operation order is
    /// exactly `QuantizedLinear::dequantize`'s (`s*(q+z)` then `*t`), so a
    /// tile equals the corresponding dense rows bit-for-bit.
    fn dequant_row_into(&self, isa: Isa, i: usize, out: &mut [f32], codes_buf: &mut [u8]) {
        simd::decode_levels_with(isa, self.row_bytes(i), self.bits, &self.lut, codes_buf, out);
        let g = self.group_size;
        for gi in 0..self.n_groups() {
            let s = self.scales.at(i, gi);
            let z = self.shifts.as_ref().map(|m| m.at(i, gi)).unwrap_or(0.0);
            let j1 = ((gi + 1) * g).min(self.cols);
            for o in &mut out[gi * g..j1] {
                *o = s * (*o + z);
            }
        }
        if let Some(t) = &self.col_scale {
            for (o, &tv) in out.iter_mut().zip(t.iter()) {
                *o *= tv;
            }
        }
    }

    /// Full dense dequantization — the "dequantize-then-matmul" baseline
    /// and the bridge to code paths that need an f32 matrix.
    pub fn to_dense(&self) -> Matrix {
        let isa = simd::active();
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut codes = vec![0u8; self.cols];
        for i in 0..self.rows {
            let row = &mut m.data[i * self.cols..(i + 1) * self.cols];
            self.dequant_row_into(isa, i, row, &mut codes);
        }
        m
    }

    /// The cache-blocked tile iterator every fused matmul entry point
    /// drives: partitions the `n` output rows into [`ROW_BLOCK`]-row tiles,
    /// runs `body(r0, r1, out)` per tile (with `out` holding `m × (r1-r0)`
    /// partials, activation-major), in parallel across the **persistent**
    /// worker pool ([`crate::util::threadpool::global`] — a condvar wake
    /// per call, not a thread spawn), and scatters the partials into the
    /// `(m, n)` result. Tiles are independent and scattered by block
    /// index, so results are deterministic regardless of `threads`.
    fn tiled_rows<F>(&self, m: usize, threads: usize, body: F) -> Matrix
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        let n = self.rows;
        let n_blocks = n.div_ceil(ROW_BLOCK);
        let blocks: Vec<usize> = (0..n_blocks).collect();
        let partials: Vec<Vec<f32>> = threadpool::map_indexed(&blocks, threads, |_, &bk| {
            let r0 = bk * ROW_BLOCK;
            let r1 = ((bk + 1) * ROW_BLOCK).min(n);
            let mut out = vec![0.0f32; m * (r1 - r0)];
            body(r0, r1, &mut out);
            out
        });
        let mut y = Matrix::zeros(m, n);
        for (bk, part) in partials.iter().enumerate() {
            let r0 = bk * ROW_BLOCK;
            let rb = ((bk + 1) * ROW_BLOCK).min(n) - r0;
            for xi in 0..m {
                y.row_mut(xi)[r0..r0 + rb].copy_from_slice(&part[xi * rb..(xi + 1) * rb]);
            }
        }
        y
    }

    /// Fused dequantize-matmul: `y = x · Wᵀ` with `x` of shape
    /// `(m, cols)`, producing `(m, rows)`.
    ///
    /// Weight rows are dequantized once per [`ROW_BLOCK`]-row tile and the
    /// tile is reused across every activation row, so the dequant cost is
    /// amortized `m`× and no full-size f32 weight matrix ever exists.
    pub fn dequant_matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols, self.cols, "dequant_matmul shape mismatch");
        let (m, n, k) = (x.rows, self.rows, self.cols);
        let threads = if m * n * k < PARALLEL_THRESHOLD { 1 } else { threads.max(1) };
        let isa = simd::active();
        self.tiled_rows(m, threads, |r0, r1, out| {
            let rb = r1 - r0;
            let mut tile = vec![0.0f32; rb * k];
            let mut codes = vec![0u8; k];
            for (ti, r) in (r0..r1).enumerate() {
                self.dequant_row_into(isa, r, &mut tile[ti * k..(ti + 1) * k], &mut codes);
            }
            for xi in 0..m {
                let xrow = x.row(xi);
                for ti in 0..rb {
                    out[xi * rb + ti] = simd::dot_with(isa, xrow, &tile[ti * k..(ti + 1) * k]);
                }
            }
        })
    }

    /// Fold the SINQ column scale into one activation vector (`xt = x ⊙ t`)
    /// and precompute the per-group sums of `xt` that carry the shift term.
    /// Writes into caller-provided buffers (`xt.len() == cols`,
    /// `gsum.len() == n_groups()`), so decode steps can reuse scratch.
    fn fold_input_into(&self, x: &[f32], xt: &mut [f32], gsum: &mut [f32]) {
        match &self.col_scale {
            Some(t) => {
                for ((o, &a), &b) in xt.iter_mut().zip(x.iter()).zip(t.iter()) {
                    *o = a * b;
                }
            }
            None => xt.copy_from_slice(x),
        }
        let g = self.group_size;
        for (gi, slot) in gsum.iter_mut().enumerate() {
            let j1 = ((gi + 1) * g).min(self.cols);
            *slot = xt[gi * g..j1].iter().sum();
        }
    }

    /// One output element of the decode kernels: group-wise
    /// `Σ_g s_g·dot(levels_g, xt_g) + s_g·z_g·gsum_g` over row `i`'s decoded
    /// levels. Both decode kernels funnel through here (with the same
    /// dispatched dot), so their results are bit-identical for any given
    /// activation row.
    fn row_accum(&self, isa: Isa, i: usize, levels: &[f32], xt: &[f32], gsum: &[f32]) -> f32 {
        let g = self.group_size;
        let mut acc = 0.0f32;
        for (gi, &gs) in gsum.iter().enumerate() {
            let j0 = gi * g;
            let j1 = ((gi + 1) * g).min(self.cols);
            let d = simd::dot_with(isa, &levels[j0..j1], &xt[j0..j1]);
            let s = self.scales.at(i, gi);
            let z = self.shifts.as_ref().map(|m| m.at(i, gi)).unwrap_or(0.0);
            acc += s * d + s * z * gs;
        }
        acc
    }

    /// Fused dequantize-matvec: `y = W · x` for one activation vector
    /// (`x.len() == cols`), the autoregressive-decode hot path.
    ///
    /// Works in code space: the column scale is folded into the input once
    /// (`xt = x ⊙ t`), per-group partial sums of `xt` carry the shift term,
    /// and each weight row is decoded to its grid levels once then reduced
    /// with the dispatched SIMD dot — full dequantized weights (with scales
    /// applied) are never materialized. The per-element arithmetic lives in
    /// `row_accum`, shared with [`QuantizedTensor::dequant_matmul_shared`],
    /// so single-sequence and batched decode agree bit-for-bit.
    pub fn dequant_matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = KernelScratch::new();
        self.dequant_matvec_with(x, &mut scratch)
    }

    /// [`QuantizedTensor::dequant_matvec`] with caller-owned scratch: the
    /// decoders keep one [`KernelScratch`] per session so the per-token
    /// loop performs no unpack/fold allocations and the SIMD kernels write
    /// into stable cache-line-aligned tiles.
    pub fn dequant_matvec_with(&self, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
        assert_eq!(x.len(), self.cols, "dequant_matvec shape mismatch");
        let isa = simd::active();
        let k = self.cols;
        scratch.codes.resize(k, 0);
        scratch.levels.resize(k);
        scratch.xt.resize(k);
        scratch.gsum.resize(self.n_groups(), 0.0);
        self.fold_input_into(x, scratch.xt.as_mut_slice(), &mut scratch.gsum);
        let mut y = vec![0.0f32; self.rows];
        for (i, yi) in y.iter_mut().enumerate() {
            simd::decode_levels_with(
                isa,
                self.row_bytes(i),
                self.bits,
                &self.lut,
                &mut scratch.codes,
                scratch.levels.as_mut_slice(),
            );
            *yi = self.row_accum(
                isa,
                i,
                scratch.levels.as_slice(),
                scratch.xt.as_slice(),
                &scratch.gsum,
            );
        }
        y
    }

    /// Two-row variant of [`QuantizedTensor::row_accum`]: one pass over
    /// row `i`'s decoded levels reduced against two folded activation rows
    /// through the 2-row microkernel ([`simd::dot2_with`]). Each lane's
    /// arithmetic — group order, scale/shift application, accumulator —
    /// is exactly `row_accum`'s, so each returned value is bitwise-equal
    /// to the corresponding single-row call.
    fn row_accum2(
        &self,
        isa: Isa,
        i: usize,
        levels: &[f32],
        x0: (&[f32], &[f32]),
        x1: (&[f32], &[f32]),
    ) -> (f32, f32) {
        let g = self.group_size;
        let (xt0, gsum0) = x0;
        let (xt1, gsum1) = x1;
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        for gi in 0..gsum0.len() {
            let j0 = gi * g;
            let j1 = ((gi + 1) * g).min(self.cols);
            let (d0, d1) = simd::dot2_with(isa, &levels[j0..j1], &xt0[j0..j1], &xt1[j0..j1]);
            let s = self.scales.at(i, gi);
            let z = self.shifts.as_ref().map(|m| m.at(i, gi)).unwrap_or(0.0);
            acc0 += s * d0 + s * z * gsum0[gi];
            acc1 += s * d1 + s * z * gsum1[gi];
        }
        (acc0, acc1)
    }

    /// Four-row variant of [`QuantizedTensor::row_accum`]; see
    /// [`QuantizedTensor::row_accum2`] for the per-lane bitwise contract.
    #[allow(clippy::too_many_arguments)]
    fn row_accum4(
        &self,
        isa: Isa,
        i: usize,
        levels: &[f32],
        x0: (&[f32], &[f32]),
        x1: (&[f32], &[f32]),
        x2: (&[f32], &[f32]),
        x3: (&[f32], &[f32]),
    ) -> [f32; 4] {
        let g = self.group_size;
        let mut acc = [0.0f32; 4];
        for gi in 0..x0.1.len() {
            let j0 = gi * g;
            let j1 = ((gi + 1) * g).min(self.cols);
            let d = simd::dot4_with(
                isa,
                &levels[j0..j1],
                &x0.0[j0..j1],
                &x1.0[j0..j1],
                &x2.0[j0..j1],
                &x3.0[j0..j1],
            );
            let s = self.scales.at(i, gi);
            let z = self.shifts.as_ref().map(|m| m.at(i, gi)).unwrap_or(0.0);
            acc[0] += s * d[0] + s * z * x0.1[gi];
            acc[1] += s * d[1] + s * z * x1.1[gi];
            acc[2] += s * d[2] + s * z * x2.1[gi];
            acc[3] += s * d[3] + s * z * x3.1[gi];
        }
        acc
    }

    /// Fused dequantize-matmul for the batched decode path: `y = x · Wᵀ`
    /// with `x` holding one activation row per live sequence. Allocates
    /// its own scratch — decoders use
    /// [`QuantizedTensor::dequant_matmul_shared_with`] to reuse theirs.
    pub fn dequant_matmul_shared(&self, x: &Matrix, threads: usize) -> Matrix {
        let mut scratch = KernelScratch::new();
        self.dequant_matmul_shared_with(x, threads, &mut scratch)
    }

    /// Fused dequantize-matmul for the batched decode path with
    /// caller-owned scratch: `y = x · Wᵀ` with `x` holding one activation
    /// row per live sequence.
    ///
    /// Each weight row's packed codes are unpacked and decoded to grid
    /// levels **once per step** and reduced against every activation row —
    /// the continuous-batching amortization (one unpack, many sequences).
    /// Batches of ≥ 2 rows go through the 4-/2-row SIMD microkernels,
    /// which share the decoded-level loads across activation rows while
    /// keeping a separate accumulator set and the single-row reduction
    /// order per row. Per activation row the arithmetic is therefore
    /// exactly [`QuantizedTensor::dequant_matvec`]'s, so batched decode
    /// reproduces single-sequence decode bit-for-bit at any batch size,
    /// and results are deterministic regardless of `threads`.
    ///
    /// The folded activation rows (`xt = x ⊙ t` plus per-group sums) live
    /// in `scratch.xt_rows`/`scratch.gsum_rows`, so steady-state decode
    /// steps perform no fold allocations (mirroring the matvec path).
    pub fn dequant_matmul_shared_with(
        &self,
        x: &Matrix,
        threads: usize,
        scratch: &mut KernelScratch,
    ) -> Matrix {
        assert_eq!(x.cols, self.cols, "dequant_matmul_shared shape mismatch");
        let (m, n, k) = (x.rows, self.rows, self.cols);
        let isa = simd::active();
        let groups = self.n_groups();
        // Row stride padded to a full 16-lane chunk so every folded row
        // starts cache-line aligned.
        let stride = k.div_ceil(16) * 16;
        scratch.xt_rows.resize(m * stride);
        scratch.gsum_rows.resize(m * groups, 0.0);
        {
            let xt_rows = scratch.xt_rows.as_mut_slice();
            for r in 0..m {
                let (xt, gsum) = (
                    &mut xt_rows[r * stride..r * stride + k],
                    &mut scratch.gsum_rows[r * groups..(r + 1) * groups],
                );
                self.fold_input_into(x.row(r), xt, gsum);
            }
        }
        let xt_rows = scratch.xt_rows.as_slice();
        let gsum_rows = &scratch.gsum_rows[..];
        let fold = |r: usize| {
            (&xt_rows[r * stride..r * stride + k], &gsum_rows[r * groups..(r + 1) * groups])
        };
        let threads = if m * n * k < PARALLEL_THRESHOLD { 1 } else { threads.max(1) };
        self.tiled_rows(m, threads, |r0, r1, out| {
            let rb = r1 - r0;
            let mut codes = vec![0u8; k];
            let mut levels = vec![0.0f32; k];
            for (ti, i) in (r0..r1).enumerate() {
                simd::decode_levels_with(
                    isa,
                    self.row_bytes(i),
                    self.bits,
                    &self.lut,
                    &mut codes,
                    &mut levels,
                );
                // Multi-row microkernels: 4-row, then 2-row, then the
                // single-row closer — every lane bitwise-equal to
                // `row_accum` (and therefore to `dequant_matvec`).
                let mut xi = 0;
                while xi + 4 <= m {
                    let y = self.row_accum4(
                        isa,
                        i,
                        &levels,
                        fold(xi),
                        fold(xi + 1),
                        fold(xi + 2),
                        fold(xi + 3),
                    );
                    out[xi * rb + ti] = y[0];
                    out[(xi + 1) * rb + ti] = y[1];
                    out[(xi + 2) * rb + ti] = y[2];
                    out[(xi + 3) * rb + ti] = y[3];
                    xi += 4;
                }
                if xi + 2 <= m {
                    let (y0, y1) = self.row_accum2(isa, i, &levels, fold(xi), fold(xi + 1));
                    out[xi * rb + ti] = y0;
                    out[(xi + 1) * rb + ti] = y1;
                    xi += 2;
                }
                if xi < m {
                    let (xt, gsum) = fold(xi);
                    out[xi * rb + ti] = self.row_accum(isa, i, &levels, xt, gsum);
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fmt::grids::Grid;
    use crate::quant::{quantize_matrix, Method, QuantConfig};
    use crate::tensor::Rng;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    fn check_parity(w: &Matrix, cfg: &QuantConfig, label: &str) {
        let q = quantize_matrix(w, cfg, None).unwrap();
        let qt = QuantizedTensor::from_linear(&q).expect(label);
        let dense = q.dequantize();
        // Packed → dense must reproduce the zoo's dequantization exactly.
        assert!(qt.to_dense().dist(&dense) < 1e-6, "{label}: to_dense mismatch");

        let mut rng = Rng::new(99);
        let x = Matrix::randn(5, w.cols, 1.0, &mut rng);
        let reference = x.matmul_nt(&dense);
        let fused = qt.dequant_matmul(&x, 2);
        assert_eq!((fused.rows, fused.cols), (5, w.rows), "{label}");
        assert!(
            max_abs_diff(&fused.data, &reference.data) < 1e-4,
            "{label}: fused matmul diverges"
        );

        let mv = qt.dequant_matvec(x.row(0));
        assert!(max_abs_diff(&mv, reference.row(0)) < 1e-4, "{label}: matvec diverges");

        let shared = qt.dequant_matmul_shared(&x, 2);
        assert!(
            max_abs_diff(&shared.data, &reference.data) < 1e-4,
            "{label}: shared decode matmul diverges"
        );
    }

    #[test]
    fn fused_matches_dense_all_bit_widths() {
        let mut rng = Rng::new(7);
        // cols=100 with g=64 → a ragged tail group; rows=37 → ragged tile.
        let w = Matrix::randn(37, 100, 0.05, &mut rng);
        for bits in [2u32, 3, 4, 5, 8] {
            for method in [Method::Rtn, Method::Sinq] {
                let cfg = QuantConfig::new(method, bits);
                check_parity(&w, &cfg, &format!("{}-{}b", method.name(), bits));
            }
        }
    }

    #[test]
    fn fused_matches_dense_table_grid() {
        let mut rng = Rng::new(8);
        let w = Matrix::randn(16, 128, 0.05, &mut rng);
        let cfg = QuantConfig::new(Method::BnB, 4).with_grid(Grid::nf4());
        check_parity(&w, &cfg, "nf4");
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(9);
        // Large enough to cross PARALLEL_THRESHOLD.
        let w = Matrix::randn(256, 128, 0.05, &mut rng);
        let q = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
        let qt = QuantizedTensor::from_linear(&q).unwrap();
        let x = Matrix::randn(32, 128, 1.0, &mut rng);
        let a = qt.dequant_matmul(&x, 1);
        let b = qt.dequant_matmul(&x, 4);
        assert_eq!(a.data, b.data, "parallel tiling must be deterministic");
        let sa = qt.dequant_matmul_shared(&x, 1);
        let sb = qt.dequant_matmul_shared(&x, 4);
        assert_eq!(sa.data, sb.data, "shared decode tiling must be deterministic");
    }

    /// The batched-decode contract: `dequant_matmul_shared` must reproduce
    /// `dequant_matvec` bit-for-bit per activation row — this is what makes
    /// batched greedy decode exactly equal to single-sequence decode.
    #[test]
    fn shared_matmul_is_bitwise_equal_to_matvec_rows() {
        let mut rng = Rng::new(21);
        // Ragged tail group (cols=100, g=64) and ragged row tile (rows=37).
        let w = Matrix::randn(37, 100, 0.05, &mut rng);
        let x = Matrix::randn(6, 100, 1.0, &mut rng);
        for bits in [2u32, 3, 4, 5, 8] {
            for method in [Method::Rtn, Method::Sinq] {
                let q = quantize_matrix(&w, &QuantConfig::new(method, bits), None).unwrap();
                let qt = QuantizedTensor::from_linear(&q).unwrap();
                let y = qt.dequant_matmul_shared(&x, 2);
                for r in 0..x.rows {
                    let mv = qt.dequant_matvec(x.row(r));
                    assert_eq!(
                        y.row(r),
                        mv.as_slice(),
                        "{} {}b row {r}: shared kernel drifted from matvec",
                        method.name(),
                        bits
                    );
                }
            }
        }
    }

    /// Scratch reuse across calls of different shapes must not change
    /// results (the decoders call `dequant_matvec_with` with one scratch
    /// across layers of different widths).
    #[test]
    fn matvec_scratch_reuse_is_bitwise_stable() {
        let mut rng = Rng::new(22);
        let w_wide = Matrix::randn(16, 96, 0.05, &mut rng);
        let w_narrow = Matrix::randn(24, 48, 0.05, &mut rng);
        let qw = QuantizedTensor::from_linear(
            &quantize_matrix(&w_wide, &QuantConfig::new(Method::Sinq, 4), None).unwrap(),
        )
        .unwrap();
        let qn = QuantizedTensor::from_linear(
            &quantize_matrix(&w_narrow, &QuantConfig::new(Method::Rtn, 3), None).unwrap(),
        )
        .unwrap();
        let xw: Vec<f32> = (0..96).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let xn: Vec<f32> = (0..48).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut scratch = KernelScratch::new();
        // Interleave shapes through one scratch; compare to fresh-scratch runs.
        for _ in 0..3 {
            assert_eq!(qw.dequant_matvec_with(&xw, &mut scratch), qw.dequant_matvec(&xw));
            assert_eq!(qn.dequant_matvec_with(&xn, &mut scratch), qn.dequant_matvec(&xn));
        }
    }

    #[test]
    fn rejects_rotated_and_codebook_layers() {
        let mut rng = Rng::new(10);
        let w = Matrix::randn(32, 64, 0.05, &mut rng);
        let q = quantize_matrix(&w, &QuantConfig::new(Method::HadamardRtn, 4), None).unwrap();
        assert!(q.hadamard);
        assert!(QuantizedTensor::from_linear(&q).is_none());
        let q = quantize_matrix(&w, &QuantConfig::new(Method::Codebook, 4), None).unwrap();
        assert!(QuantizedTensor::from_linear(&q).is_none());
    }

    #[test]
    fn packed_bytes_reflect_bit_width() {
        let mut rng = Rng::new(11);
        let w = Matrix::randn(64, 128, 0.05, &mut rng);
        let q4 = quantize_matrix(&w, &QuantConfig::new(Method::Rtn, 4), None).unwrap();
        let q8 = quantize_matrix(&w, &QuantConfig::new(Method::Rtn, 8), None).unwrap();
        let t4 = QuantizedTensor::from_linear(&q4).unwrap();
        let t8 = QuantizedTensor::from_linear(&q8).unwrap();
        assert_eq!(t4.packed_bytes() * 2, t8.packed_bytes());
        assert_eq!(t8.packed_bytes(), 64 * 128);
    }
}
