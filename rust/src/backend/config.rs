//! Typed engine configuration shared by every decode entry point.
//!
//! [`EngineConfig`] collapses the constructor sprawl that used to pick KV
//! precision, batch width, and capacity per call site
//! (`with_max_batch`/`with_kv_bits`, `new`/`new_with_kv`/`with_kv`) into
//! one builder that flows unchanged from the CLI through
//! [`crate::backend::build_native`], the quantize-and-serve pipeline, and
//! the HTTP server — so the paged-KV knobs (page size, pool size) did not
//! have to add a third generation of `new_with_*` constructors.

use crate::backend::fwd::{KvBits, SampleCfg};

/// Everything a decoder needs to size itself: KV precision, concurrency,
/// per-sequence context cap, page-pool geometry, and the default sampling
/// mode. Plain data — copy it freely across threads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    /// KV-cache element precision (`--kv-bits 32|8`).
    pub kv_bits: KvBits,
    /// Serving concurrency cap: scoring batch size and generation slots.
    pub max_batch: usize,
    /// Per-sequence context cap in KV positions (`--max-context`).
    pub max_context: usize,
    /// KV page granularity in positions; one page spans all layers.
    pub page_size: usize,
    /// Page-pool size override (`--kv-pages`); `None` sizes the pool to
    /// the contiguous worst case, `max_batch × ceil(max_context /
    /// page_size)` pages.
    pub pages: Option<usize>,
    /// Default sampling for requests that do not carry their own
    /// [`SampleCfg`]; `None` decodes greedily.
    pub sample: Option<SampleCfg>,
    /// Drift-sentinel sampling rate: every `N`th decode step recomputes
    /// one live row's logits through the forced-scalar kernel path and
    /// feeds the comparison into [`crate::obs::drift`]. `0` (the default)
    /// disables the sentinel entirely (`--drift-sample N`).
    pub drift_sample: usize,
    /// Server-wide deadline ceiling in milliseconds
    /// (`--request-timeout-ms`): every request's effective deadline is
    /// clamped to this, whether or not it asked for its own `deadline_ms`.
    /// `0` (the default) means no server-imposed deadline.
    pub request_timeout_ms: u64,
    /// Worker-thread count for the persistent pool (`--threads`). `0`
    /// (the default) auto-sizes to every available core; the
    /// `SINQ_THREADS` environment variable overrides either setting (see
    /// [`crate::util::threadpool::resolve_threads`]).
    pub threads: usize,
}

/// Default serving concurrency: scoring batch size and generation slots.
pub const DEFAULT_MAX_BATCH: usize = 4;

/// Default KV page granularity (positions per page).
pub const DEFAULT_PAGE_SIZE: usize = 16;

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            kv_bits: KvBits::F32,
            max_batch: DEFAULT_MAX_BATCH,
            max_context: 512,
            page_size: DEFAULT_PAGE_SIZE,
            pages: None,
            sample: None,
            drift_sample: 0,
            request_timeout_ms: 0,
            threads: 0,
        }
    }
}

impl EngineConfig {
    pub fn new() -> EngineConfig {
        EngineConfig::default()
    }

    pub fn with_kv_bits(mut self, kv_bits: KvBits) -> EngineConfig {
        self.kv_bits = kv_bits;
        self
    }

    /// Minimum 1 (a decoder needs at least one slot).
    pub fn with_max_batch(mut self, max_batch: usize) -> EngineConfig {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Minimum 1 position.
    pub fn with_max_context(mut self, max_context: usize) -> EngineConfig {
        self.max_context = max_context.max(1);
        self
    }

    /// Minimum 1 position per page.
    pub fn with_page_size(mut self, page_size: usize) -> EngineConfig {
        self.page_size = page_size.max(1);
        self
    }

    /// Explicit page-pool size; `None` restores the derived default.
    pub fn with_pages(mut self, pages: Option<usize>) -> EngineConfig {
        self.pages = pages;
        self
    }

    pub fn with_sample(mut self, sample: Option<SampleCfg>) -> EngineConfig {
        self.sample = sample;
        self
    }

    /// Drift-sentinel sampling rate (`0` disables).
    pub fn with_drift_sample(mut self, drift_sample: usize) -> EngineConfig {
        self.drift_sample = drift_sample;
        self
    }

    /// Server-wide deadline ceiling in milliseconds (`0` disables).
    pub fn with_request_timeout_ms(mut self, request_timeout_ms: u64) -> EngineConfig {
        self.request_timeout_ms = request_timeout_ms;
        self
    }

    /// Worker-thread count for the persistent pool (`0` = auto).
    pub fn with_threads(mut self, threads: usize) -> EngineConfig {
        self.threads = threads;
        self
    }

    /// The thread count decode actually runs with: `threads` resolved
    /// through the `SINQ_THREADS` override and the all-cores default.
    pub fn effective_threads(&self) -> usize {
        crate::util::threadpool::resolve_threads(self.threads)
    }

    /// A request's effective deadline budget in milliseconds: its own
    /// `deadline_ms` clamped by the server-wide `request_timeout_ms`
    /// ceiling (either side `0`/`None` means "no bound from that side");
    /// `None` when neither imposes one.
    pub fn effective_deadline_ms(&self, deadline_ms: Option<u64>) -> Option<u64> {
        match (deadline_ms.filter(|&d| d > 0), self.request_timeout_ms) {
            (None, 0) => None,
            (Some(d), 0) => Some(d),
            (None, t) => Some(t),
            (Some(d), t) => Some(d.min(t)),
        }
    }

    /// Page size clamped to at least one position.
    pub fn page_positions(&self) -> usize {
        self.page_size.max(1)
    }

    /// Resolved page-pool size: the explicit override, or the contiguous
    /// worst case `max_batch × ceil(max_context / page_size)` — the same
    /// memory the old per-slot reservation preallocated, now claimable by
    /// any slot.
    pub fn pages_total(&self) -> usize {
        let ps = self.page_positions();
        self.pages
            .unwrap_or_else(|| self.max_batch.max(1) * ((self.max_context.max(1) + ps - 1) / ps))
            .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pool_matches_contiguous_worst_case() {
        let cfg = EngineConfig::new().with_max_batch(3).with_max_context(100).with_page_size(16);
        // ceil(100 / 16) = 7 pages per slot, 3 slots.
        assert_eq!(cfg.pages_total(), 21);
        assert_eq!(cfg.page_positions(), 16);
    }

    #[test]
    fn explicit_pool_and_clamps_win() {
        let cfg = EngineConfig::new().with_pages(Some(5)).with_page_size(0).with_max_batch(0);
        assert_eq!(cfg.pages_total(), 5);
        assert_eq!(cfg.page_positions(), 1);
        assert_eq!(cfg.max_batch, 1);
        let zero = EngineConfig::new().with_pages(Some(0));
        assert_eq!(zero.pages_total(), 1, "pool is never empty");
    }

    #[test]
    fn builder_carries_sampling_default() {
        let s = SampleCfg { temperature: 0.9, top_k: 5, seed: 11 };
        let cfg = EngineConfig::new().with_sample(Some(s)).with_kv_bits(KvBits::Q8);
        assert_eq!(cfg.sample, Some(s));
        assert_eq!(cfg.kv_bits, KvBits::Q8);
    }

    #[test]
    fn drift_sentinel_defaults_off() {
        assert_eq!(EngineConfig::new().drift_sample, 0);
        let cfg = EngineConfig::new().with_drift_sample(16);
        assert_eq!(cfg.drift_sample, 16);
    }

    #[test]
    fn threads_default_to_auto_and_resolve_through_env() {
        let cfg = EngineConfig::new();
        assert_eq!(cfg.threads, 0, "default is auto");
        assert!(cfg.effective_threads() >= 1);
        let two = EngineConfig::new().with_threads(2);
        assert_eq!(two.threads, 2);
        // Under a CI `SINQ_THREADS` matrix leg the env override wins;
        // otherwise the explicit request is the effective count.
        match std::env::var("SINQ_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(n) if n > 0 => assert_eq!(two.effective_threads(), n),
            _ => assert_eq!(two.effective_threads(), 2),
        }
    }

    #[test]
    fn effective_deadline_clamps_per_request_by_server_ceiling() {
        let open = EngineConfig::new();
        assert_eq!(open.request_timeout_ms, 0);
        assert_eq!(open.effective_deadline_ms(None), None);
        assert_eq!(open.effective_deadline_ms(Some(0)), None, "0 means unset");
        assert_eq!(open.effective_deadline_ms(Some(250)), Some(250));
        let capped = EngineConfig::new().with_request_timeout_ms(1_000);
        assert_eq!(capped.effective_deadline_ms(None), Some(1_000));
        assert_eq!(capped.effective_deadline_ms(Some(250)), Some(250));
        assert_eq!(capped.effective_deadline_ms(Some(5_000)), Some(1_000));
    }
}
