//! Continuous-batching generation engine over the native backend.
//!
//! [`BatchDecoder`] is the serving-scale sibling of
//! [`crate::backend::NativeDecoder`]: it maintains one KV-cache slot per
//! concurrent sequence, admits queued requests into free slots and retires
//! finished ones **between steps** (continuous batching, not static), and
//! executes each decode step as fused matmuls over the stacked activation
//! rows of all live sequences. Every packed weight tile is therefore
//! unpacked once per step instead of once per sequence — the amortization
//! that makes weight-only low-bit schemes viable in serving.
//!
//! Exactness contract: every kernel the batched step touches
//! ([`QuantizedTensor::dequant_matmul_shared`] via
//! `LayerWeight::decode_matmul`, the shared `causal_attend`, `mlp_forward`,
//! `rmsnorm`/`rope`) runs the same f32 arithmetic per sequence as the
//! single-sequence decoder, so greedy tokens match [`NativeDecoder`]
//! bit-for-bit at any batch size and any admission order.
//!
//! [`QuantizedTensor::dequant_matmul_shared`]:
//! crate::backend::QuantizedTensor::dequant_matmul_shared
//! [`NativeDecoder`]: crate::backend::NativeDecoder

use std::collections::VecDeque;

use crate::backend::native::{
    argmax, causal_attend, mlp_forward, MlpRefs, NativeBackend, ResolvedModel,
};
use crate::backend::simd::KernelScratch;
use crate::model::forward::{add_inplace, rmsnorm, rope, silu};
use crate::tensor::Matrix;

/// One generation request queued for slot admission.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Caller-chosen identifier; outputs are reported against it.
    pub id: usize,
    pub prompt: Vec<u8>,
    /// Number of tokens to generate (greedy).
    pub max_new: usize,
}

/// Validate that a request fits one preallocated KV slot. Shared by
/// [`BatchDecoder::submit`] and the HTTP admission check in
/// [`crate::serve`], so the serving front-end rejects oversized requests
/// with exactly the same KV-capacity text the decoder itself uses.
pub fn ensure_fits(
    capacity: usize,
    id: usize,
    prompt_len: usize,
    max_new: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(prompt_len > 0, "request {id}: empty prompt");
    // Saturating: a request with max_new near usize::MAX must hit the
    // capacity error below, not wrap past it (this guards a network input).
    let needed = prompt_len.saturating_add(max_new.saturating_sub(1));
    anyhow::ensure!(
        needed <= capacity,
        "request {id}: prompt of {prompt_len} tokens + {max_new} generated needs {needed} KV \
         positions but each slot preallocated {capacity} (KV capacity); raise the decoder \
         capacity or shorten the request"
    );
    Ok(())
}

/// A finished generation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenOutput {
    pub id: usize,
    pub tokens: Vec<u8>,
    /// Decode steps this sequence was live for (prompt + generated − 1).
    pub steps: usize,
}

/// Aggregate engine counters for throughput reporting.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    /// Fused decode steps executed.
    pub steps: usize,
    /// Sequence-tokens processed (Σ live batch size over all steps).
    pub tokens: usize,
    /// Largest live batch observed in one step.
    pub peak_batch: usize,
    /// Requests completed.
    pub completed: usize,
}

/// A sequence occupying a slot: its request plus decode progress.
struct Active {
    id: usize,
    prompt: Vec<u8>,
    /// Tokens fed into the model so far (prompt first, then generated).
    fed: usize,
    out: Vec<u8>,
    max_new: usize,
    /// Next KV position to write == this sequence's context length.
    pos: usize,
}

impl Active {
    /// The token this sequence feeds on the next step: the next prompt
    /// token during prefill, the last greedy token afterwards.
    fn next_input(&self) -> u8 {
        if self.fed < self.prompt.len() {
            self.prompt[self.fed]
        } else {
            *self.out.last().expect("generated token")
        }
    }
}

/// Per-slot KV storage: one `(capacity, d)` matrix per layer for K and V.
/// Slots are recycled by resetting the position — attention only ever reads
/// rows `0..=pos`, so stale rows from an evicted sequence are never touched.
struct SlotCache {
    k: Vec<Matrix>,
    v: Vec<Matrix>,
}

/// Decoder-owned per-step scratch: the stacked activations, RoPE angles,
/// attention context/scores, and MLP activation tiles every step used to
/// allocate (`Matrix::zeros` per step and per layer) live here and are
/// shape-`reset` instead — reallocation only happens when the live batch
/// grows past its high-water mark. The [`KernelScratch`] serves the per-row
/// MoE path's quantized matvecs.
struct BatchScratch {
    /// Residual stream, one row per live sequence.
    h: Matrix,
    /// Per-sequence RoPE angles (each row at its own position).
    cos: Matrix,
    sin: Matrix,
    /// Attention context accumulator (zeroed per layer).
    ctx: Matrix,
    /// SwiGLU activation tile.
    act: Matrix,
    /// Per-row MoE output rows (switch-MoE routes per sequence).
    moe_y: Matrix,
    /// Attention score buffer (`pos + 1` entries, reused across rows).
    att: Vec<f32>,
    /// Fused-kernel scratch for the per-row MoE matvec path.
    kernel: KernelScratch,
}

/// Continuous-batching greedy decoder over a [`NativeBackend`].
///
/// ```text
/// submit(..) → pending ─admit─▶ slots (≤ max_slots live) ─retire─▶ finished
///                                  │ step(): one fused forward over
///                                  ▼         all live rows
/// ```
///
/// [`BatchDecoder::step`] admits pending requests into free slots, advances
/// every live sequence by one token through fused stacked-row matmuls, and
/// retires sequences that produced their `max_new`-th token — freeing the
/// slot for the next pending request on the following step.
pub struct BatchDecoder<'a> {
    model: ResolvedModel<'a>,
    /// Per-slot KV capacity (positions).
    capacity: usize,
    slots: Vec<Option<Active>>,
    caches: Vec<SlotCache>,
    pending: VecDeque<GenRequest>,
    finished: Vec<GenOutput>,
    /// `(request id, token)` pairs emitted by the most recent step, in slot
    /// order — the hook streaming consumers read between steps.
    emitted: Vec<(usize, u8)>,
    scratch: BatchScratch,
    stats: BatchStats,
}

impl<'a> BatchDecoder<'a> {
    /// Resolve the backend's weights and preallocate `max_slots` KV-cache
    /// slots of `capacity` positions each.
    pub fn new(
        be: &'a NativeBackend,
        max_slots: usize,
        capacity: usize,
    ) -> anyhow::Result<BatchDecoder<'a>> {
        anyhow::ensure!(max_slots >= 1, "batch decoder needs at least one slot");
        let model = ResolvedModel::new(be)?;
        let cap = capacity.max(1);
        let (layers, d) = (model.cfg.layers, model.cfg.d);
        let caches = (0..max_slots)
            .map(|_| SlotCache {
                k: (0..layers).map(|_| Matrix::zeros(cap, d)).collect(),
                v: (0..layers).map(|_| Matrix::zeros(cap, d)).collect(),
            })
            .collect();
        Ok(BatchDecoder {
            model,
            capacity: cap,
            slots: (0..max_slots).map(|_| None).collect(),
            caches,
            pending: VecDeque::new(),
            finished: Vec::new(),
            emitted: Vec::new(),
            scratch: BatchScratch {
                h: Matrix::zeros(0, 0),
                cos: Matrix::zeros(0, 0),
                sin: Matrix::zeros(0, 0),
                ctx: Matrix::zeros(0, 0),
                act: Matrix::zeros(0, 0),
                moe_y: Matrix::zeros(0, 0),
                att: Vec::with_capacity(cap),
                kernel: KernelScratch::new(),
            },
            stats: BatchStats::default(),
        })
    }

    /// Queue a generation request. Requests that cannot fit a KV slot are
    /// rejected up front with a clear error instead of overflowing the
    /// cache mid-decode; `max_new == 0` completes immediately.
    pub fn submit(&mut self, id: usize, prompt: &[u8], max_new: usize) -> anyhow::Result<()> {
        ensure_fits(self.capacity, id, prompt.len(), max_new)?;
        if max_new == 0 {
            self.finished.push(GenOutput { id, tokens: Vec::new(), steps: 0 });
            self.stats.completed += 1;
            return Ok(());
        }
        self.pending.push_back(GenRequest { id, prompt: prompt.to_vec(), max_new });
        Ok(())
    }

    /// Move queued requests into free slots (continuous admission).
    fn admit(&mut self) {
        while !self.pending.is_empty() {
            let free = self.slots.iter().position(Option::is_none);
            let si = match free {
                Some(si) => si,
                None => break,
            };
            let req = self.pending.pop_front().expect("non-empty pending queue");
            self.slots[si] = Some(Active {
                id: req.id,
                prompt: req.prompt,
                fed: 0,
                out: Vec::new(),
                max_new: req.max_new,
                pos: 0,
            });
        }
    }

    /// Record one step's logits for a live slot: advance its position,
    /// greedily emit once the prompt is consumed, retire when done.
    fn advance(&mut self, si: usize, logits: &[f32]) {
        let a = self.slots[si].as_mut().expect("live slot");
        a.pos += 1;
        a.fed += 1;
        if a.fed >= a.prompt.len() {
            let tok = argmax(logits) as u8;
            a.out.push(tok);
            self.emitted.push((a.id, tok));
            if a.out.len() >= a.max_new {
                let done = self.slots[si].take().expect("live slot");
                let out = GenOutput { id: done.id, tokens: done.out, steps: done.fed };
                self.finished.push(out);
                self.stats.completed += 1;
            }
        }
    }

    /// One continuous-batching decode step: admit pending requests, advance
    /// every live sequence by one token through fused stacked-row matmuls
    /// (one weight-tile unpack shared by all sequences), retire finished
    /// ones. Returns the number of sequences advanced; 0 means idle.
    pub fn step(&mut self) -> anyhow::Result<usize> {
        self.emitted.clear();
        self.admit();
        let n_slots = self.slots.len();
        let live: Vec<usize> = (0..n_slots).filter(|&i| self.slots[i].is_some()).collect();
        if live.is_empty() {
            return Ok(0);
        }
        let model = &self.model;
        let cfg = model.cfg;
        let (d, hd) = (cfg.d, cfg.head_dim());
        let b = live.len();

        // Split borrows: slots/model are read; caches and the step scratch
        // (all distinct fields of `self`) are written.
        let slots = &self.slots;
        let caches = &mut self.caches;
        let BatchScratch { h, cos, sin, ctx, act, moe_y, att, kernel } = &mut self.scratch;

        // Stack this step's input embeddings and RoPE angles, one row per
        // live sequence (each at its own position), into reused scratch.
        h.reset(b, d);
        cos.reset(b, hd / 2);
        sin.reset(b, hd / 2);
        for (r, &si) in live.iter().enumerate() {
            let a = slots[si].as_ref().expect("live slot");
            h.row_mut(r).copy_from_slice(model.embed.row(a.next_input() as usize));
            model.rope_angles_into(a.pos, cos.row_mut(r), sin.row_mut(r));
        }

        for (l, layer) in model.layers.iter().enumerate() {
            // --- Attention block: fused projections over all live rows ---
            let x = rmsnorm(h, layer.ln1, cfg.eps);
            let q = layer.wq.decode_matmul(&x, model.threads);
            let k = layer.wk.decode_matmul(&x, model.threads);
            let v = layer.wv.decode_matmul(&x, model.threads);
            let (q, k) = (rope(&q, cos, sin, cfg.heads), rope(&k, cos, sin, cfg.heads));

            ctx.reset(b, d);
            for (r, &si) in live.iter().enumerate() {
                let pos = slots[si].as_ref().expect("live slot").pos;
                let cache = &mut caches[si];
                cache.k[l].row_mut(pos).copy_from_slice(k.row(r));
                cache.v[l].row_mut(pos).copy_from_slice(v.row(r));
                causal_attend(
                    q.row(r),
                    &cache.k[l],
                    &cache.v[l],
                    pos,
                    cfg.heads,
                    hd,
                    ctx.row_mut(r),
                    att,
                );
            }
            let o = layer.wo.decode_matmul(ctx, model.threads);
            add_inplace(h, &o);

            // --- MLP block ---
            let x = rmsnorm(h, layer.ln2, cfg.eps);
            match &layer.mlp {
                MlpRefs::Dense(w) => {
                    let g = w.wg.decode_matmul(&x, model.threads);
                    let u = w.wu.decode_matmul(&x, model.threads);
                    act.reset(b, cfg.ffn);
                    for i in 0..b * cfg.ffn {
                        act.data[i] = silu(g.data[i]) * u.data[i];
                    }
                    let y = w.wd.decode_matmul(act, model.threads);
                    add_inplace(h, &y);
                }
                moe => {
                    // Switch-MoE routes per sequence; rows picking different
                    // experts cannot share a matmul, so keep the per-row
                    // path (bitwise equal to the single-sequence decoder).
                    moe_y.reset(b, d);
                    for r in 0..b {
                        moe_y.row_mut(r).copy_from_slice(&mlp_forward(moe, x.row(r), kernel));
                    }
                    add_inplace(h, moe_y);
                }
            }
        }

        let hf = rmsnorm(h, model.ln_f, cfg.eps);
        let logits = model.lm_head.decode_matmul(&hf, model.threads);

        self.stats.steps += 1;
        self.stats.tokens += b;
        self.stats.peak_batch = self.stats.peak_batch.max(b);
        for (r, &si) in live.iter().enumerate() {
            self.advance(si, logits.row(r));
        }
        Ok(b)
    }

    /// Drive [`BatchDecoder::step`] until every submitted request finished;
    /// returns the outputs ordered by request id.
    pub fn run(&mut self) -> anyhow::Result<Vec<GenOutput>> {
        while self.step()? > 0 {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|o| o.id);
        Ok(out)
    }

    /// Engine counters accumulated so far.
    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Sequences currently occupying slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Per-slot KV capacity (positions).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drain finished outputs without waiting for the queue to empty
    /// (streaming consumers call this between steps).
    pub fn take_finished(&mut self) -> Vec<GenOutput> {
        std::mem::take(&mut self.finished)
    }

    /// `(request id, token)` pairs the most recent [`BatchDecoder::step`]
    /// emitted, in slot order. This is the per-step hook the streaming
    /// serving front-end ([`crate::serve`]) forwards into per-request
    /// channels so SSE bytes flush mid-decode; tokens also accumulate into
    /// the request's [`GenOutput`] unchanged.
    pub fn emitted(&self) -> &[(usize, u8)] {
        &self.emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeDecoder;
    use crate::model::{ModelConfig, ModelWeights};

    fn pico_backend() -> NativeBackend {
        let cfg = ModelConfig::family("pico").unwrap();
        NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, 31))
    }

    #[test]
    fn idle_decoder_steps_zero() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 8).unwrap();
        assert_eq!(dec.step().unwrap(), 0);
        assert_eq!(dec.live(), 0);
        assert_eq!(dec.stats().steps, 0);
    }

    #[test]
    fn single_request_matches_native_decoder() {
        let nb = pico_backend();
        let expected = {
            let mut d = NativeDecoder::new(&nb, 32).unwrap();
            d.generate(b"hello", 6).unwrap()
        };
        let mut dec = BatchDecoder::new(&nb, 4, 32).unwrap();
        dec.submit(7, b"hello", 6).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, 7);
        assert_eq!(outs[0].tokens, expected);
        assert_eq!(outs[0].steps, 5 + 6 - 1);
    }

    #[test]
    fn more_requests_than_slots_recycles_and_completes_all() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        // Staggered lengths force retirement at different steps.
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            dec.submit(i, &[b'a' + i as u8, b'!'], *n).unwrap();
        }
        assert_eq!(dec.pending(), 5);
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 5);
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            assert_eq!(outs[i].id, i);
            assert_eq!(outs[i].tokens.len(), *n);
        }
        let stats = dec.stats();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.peak_batch, 2, "only two slots exist");
        // Σ per-sequence steps == Σ live batch sizes over all steps.
        let seq_steps: usize = outs.iter().map(|o| o.steps).sum();
        assert_eq!(stats.tokens, seq_steps);
    }

    #[test]
    fn zero_max_new_completes_immediately() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 8).unwrap();
        dec.submit(3, b"xy", 0).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs, vec![GenOutput { id: 3, tokens: Vec::new(), steps: 0 }]);
    }

    #[test]
    fn submit_rejects_requests_beyond_slot_capacity() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 4).unwrap();
        let err = dec.submit(0, b"too long for four", 2).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        let err = dec.submit(1, b"ab", 9).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        dec.submit(2, b"ab", 3).unwrap(); // 2 + 3 − 1 = 4 fits exactly
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn emitted_tokens_stream_exactly_the_final_outputs() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 16).unwrap();
        dec.submit(0, b"ab", 3).unwrap();
        dec.submit(1, b"wxyz", 2).unwrap();
        dec.submit(2, b"q!", 4).unwrap(); // waits for a recycled slot
        let mut streamed: std::collections::BTreeMap<usize, Vec<u8>> = Default::default();
        while dec.step().unwrap() > 0 {
            for &(id, tok) in dec.emitted() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        assert!(dec.emitted().is_empty(), "idle step must clear emissions");
        let mut outs = dec.take_finished();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 3);
        for out in outs {
            assert_eq!(streamed[&out.id], out.tokens, "request {}", out.id);
        }
    }

    #[test]
    fn generate_batch_entry_point_matches_sequential_generate() {
        let nb = pico_backend();
        let prompts: Vec<&[u8]> = vec![b"one", b"second prompt", b"3rd"];
        let max_new = [5usize, 3, 8];
        let batched = nb.generate_batch(&prompts, &max_new).unwrap();
        for ((p, &n), got) in prompts.iter().zip(&max_new).zip(&batched) {
            let single = nb.generate(p, n).unwrap();
            assert_eq!(got, &single, "prompt {:?}", String::from_utf8_lossy(p));
        }
    }
}
