//! Continuous-batching generation engine over the native backend.
//!
//! [`BatchDecoder`] is the serving-scale sibling of
//! [`crate::backend::NativeDecoder`]: it admits queued requests into slots
//! and retires finished ones **between steps** (continuous batching, not
//! static), and advances all live sequences through the unified decode
//! step ([`crate::backend::fwd::decode_rows`]) — fused stacked-row
//! matmuls, one weight-tile unpack per step shared by every live sequence.
//!
//! KV memory is a **paged pool** ([`crate::backend::paged::PagedKv`]):
//! slots map logical positions through per-slot page tables into a fixed
//! set of pages, claimed lazily as decode advances. When the pool runs
//! dry mid-step the decoder first evicts prefix-cache pages, then
//! preempts the *youngest* live sequence back to the queue — older
//! requests always finish, so the engine degrades to FIFO instead of
//! crashing. Retired sequences donate their full pages to a prefix cache
//! ([`crate::backend::paged::PrefixCache`]); a new request whose prompt
//! shares a cached token prefix maps those pages copy-free and skips
//! prefill for the shared span.
//!
//! Exactness contract: the batched and single-sequence decoders run the
//! *same* step function, and the paged stores replicate the contiguous
//! KV arithmetic with only the row index translated — so greedy tokens
//! match [`NativeDecoder`] bit-for-bit at any batch size, any admission
//! order, and both KV precisions; prefix-hit and preempted-then-resumed
//! decodes reproduce the cold tokens exactly.
//!
//! Per-request token selection goes through the core's
//! [`TokenPicker`] hook: greedy argmax by default, seeded
//! temperature/top-k sampling via [`BatchDecoder::submit_sampled`] —
//! reproducible across runs and batch placements because the RNG stream is
//! per request.
//!
//! [`NativeDecoder`]: crate::backend::NativeDecoder

use std::collections::VecDeque;
use std::time::Instant;

use crate::backend::config::EngineConfig;
use crate::backend::fwd::{
    decode_rows, AttnScratch, DecodeScratch, KvArena, KvBits, SampleCfg, StepRow, TokenPicker,
};
use crate::backend::native::{NativeBackend, ResolvedModel};
use crate::backend::paged::{PagedKv, PrefixCache};
use crate::backend::simd::{self, Isa};
use crate::obs::drift;
use crate::obs::fault::{self, Site};
use crate::obs::journal::{self, EventKind};
use crate::obs::profiler::{self, Phase};
use crate::tensor::Matrix;

/// One generation request queued for slot admission.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Caller-chosen identifier; outputs are reported against it.
    pub id: usize,
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Seeded sampling parameters; `None` decodes greedily.
    pub sample: Option<SampleCfg>,
    /// Absolute wall-clock deadline; past it the request is retired with
    /// `finish_reason: "timeout"` at the next step boundary (queue wait
    /// counts — the clock starts where the caller computed the instant).
    pub deadline: Option<Instant>,
}

/// Validate that a request can ever decode to completion: its positions
/// must fit the per-sequence context cap and its pages the pool. Shared
/// by [`BatchDecoder::submit`] and the HTTP admission check in
/// [`crate::serve`], so the serving front-end rejects oversized requests
/// with exactly the same KV-capacity text the decoder itself uses.
pub fn ensure_fits(
    capacity: usize,
    page_size: usize,
    pages_total: usize,
    id: usize,
    prompt_len: usize,
    max_new: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(prompt_len > 0, "request {id}: empty prompt");
    // Saturating: a request with max_new near usize::MAX must hit the
    // capacity error below, not wrap past it (this guards a network input).
    let needed = prompt_len.saturating_add(max_new.saturating_sub(1));
    anyhow::ensure!(
        needed <= capacity,
        "request {id}: prompt of {prompt_len} tokens + {max_new} generated needs {needed} KV \
         positions but sequences are capped at {capacity} (KV capacity); raise the decoder \
         capacity or shorten the request"
    );
    let ps = page_size.max(1);
    let pages = (needed + ps - 1) / ps;
    anyhow::ensure!(
        pages <= pages_total,
        "request {id}: {needed} KV positions need {pages} pages of {ps} but the page pool's \
         capacity is {pages_total} pages total; raise --kv-pages or shorten the request"
    );
    Ok(())
}

/// A finished generation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenOutput {
    pub id: usize,
    pub tokens: Vec<u8>,
    /// Decode rows this sequence consumed (prompt + generated − 1 when it
    /// was never preempted and hit no cached prefix; less after a prefix
    /// hit, more after preemption replay).
    pub steps: usize,
    /// Why the request retired: `"length"` (decoded to `max_new`) or
    /// `"timeout"` (deadline expired; `tokens` holds the partial decode).
    pub finish_reason: &'static str,
}

/// Aggregate engine counters for throughput reporting.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    /// Fused decode steps executed.
    pub steps: usize,
    /// Sequence-tokens processed (Σ live batch size over all steps).
    pub tokens: usize,
    /// Largest live batch observed in one step.
    pub peak_batch: usize,
    /// Requests completed.
    pub completed: usize,
    /// Live sequences evicted by [`BatchDecoder::cancel`] before finishing.
    pub evicted: usize,
    /// Live sequences preempted back to the queue when the page pool ran
    /// dry (they resume later; nothing is lost).
    pub preempted: usize,
    /// Admissions that mapped at least one prefix-cached page.
    pub prefix_hits: usize,
    /// Prompt positions skipped through prefix-cache page reuse.
    pub prefix_tokens_reused: usize,
    /// Requests retired with `finish_reason: "timeout"` (deadline expired
    /// while queued or live).
    pub timeouts: usize,
}

/// What [`BatchDecoder::cancel`] found for the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Removed from the pending queue before ever occupying a slot.
    Pending,
    /// Evicted from a live KV slot (freed at this step boundary), or
    /// dropped while awaiting re-admission after a preemption.
    Evicted,
    /// Unknown id (already finished or never submitted).
    NotFound,
}

/// A sequence occupying a slot: its tokens plus decode progress.
struct Active {
    id: usize,
    /// Prompt followed by every generated token.
    seq: Vec<u8>,
    prompt_len: usize,
    max_new: usize,
    /// Next KV position to write == index of the next `seq` token to feed.
    pos: usize,
    /// Decode rows consumed so far (including replay after preemption).
    steps: usize,
    /// Token-selection hook (greedy or seeded sampling). Survives
    /// preemption, so the sampled RNG stream never restarts.
    picker: TokenPicker,
    /// Admission order; preemption victims are the youngest by birth.
    birth: u64,
    /// Absolute deadline; checked at step boundaries (survives preemption).
    deadline: Option<Instant>,
}

/// Queue entry: a fresh request, or a preempted sequence awaiting
/// re-admission (pushed to the *front* so it resumes first).
enum Pending {
    Fresh(GenRequest),
    Resume(Active),
}

/// Continuous-batching decoder over a [`NativeBackend`].
///
/// ```text
/// submit(..) → pending ─admit─▶ slots (≤ max_batch live) ─retire─▶ finished
///                ▲                 │ step(): claim pages, one fused
///                └── preempt ──────┘         decode_rows over all live rows
/// ```
///
/// [`BatchDecoder::step`] admits pending requests into free slots (mapping
/// prefix-cached pages first), claims this step's KV pages oldest-first
/// (evicting cached pages, then preempting the youngest sequence if the
/// pool is dry), advances every live sequence by one token through the
/// unified decode step, and retires sequences that produced their
/// `max_new`-th token — donating their full pages to the prefix cache and
/// freeing the slot. [`BatchDecoder::cancel`] evicts a live sequence at
/// the step boundary (the serving front-end calls it when a client
/// disconnects mid-stream).
pub struct BatchDecoder<'a> {
    model: ResolvedModel<'a>,
    /// Per-sequence context cap (positions).
    capacity: usize,
    /// Sampling used when a request carries no [`SampleCfg`] of its own.
    default_sample: Option<SampleCfg>,
    slots: Vec<Option<Active>>,
    kv: PagedKv,
    prefix: PrefixCache,
    pending: VecDeque<Pending>,
    finished: Vec<GenOutput>,
    /// `(request id, token)` pairs emitted by the most recent step, in slot
    /// order — the hook streaming consumers read between steps.
    emitted: Vec<(usize, u8)>,
    /// Request ids moved from the pending queue into a slot since the last
    /// [`BatchDecoder::drain_admitted`] — the serving engine reads these to
    /// stamp queue-wait at the moment of admission. Re-admissions after a
    /// preemption are not repeated here.
    admitted: Vec<usize>,
    scratch: DecodeScratch,
    stats: BatchStats,
    births: u64,
    /// Drift-sentinel sampling rate (`EngineConfig::drift_sample`); every
    /// `N`th step recomputes one live row through the forced-scalar kernel
    /// path and reports the comparison into [`crate::obs::drift`]. 0 = off.
    drift_sample: usize,
}

/// Read-only view of the paged pool for the drift sentinel's scalar
/// recompute: `write` is a no-op so the recomputation can never perturb
/// the live KV state the fast path already wrote (`attend` only reads).
struct ReadOnlyKv<'k>(&'k PagedKv);

impl KvArena for ReadOnlyKv<'_> {
    fn write(&mut self, _slot: usize, _layer: usize, _pos: usize, _k: &[f32], _v: &[f32]) {}

    fn attend(
        &self,
        slot: usize,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    ) {
        self.0.attend(slot, layer, q, pos, ctx, s, threads);
    }
}

impl<'a> BatchDecoder<'a> {
    /// Resolve the backend's weights and build a paged KV pool sized for
    /// `max_slots` sequences of `capacity` positions, at the backend's
    /// configured engine defaults (KV precision, page size).
    pub fn new(
        be: &'a NativeBackend,
        max_slots: usize,
        capacity: usize,
    ) -> anyhow::Result<BatchDecoder<'a>> {
        let cfg =
            be.engine().with_max_batch(max_slots).with_max_context(capacity).with_pages(None);
        BatchDecoder::with_config(be, &cfg)
    }

    /// Build from a full [`EngineConfig`] (KV bits, slots, context cap,
    /// page geometry, sampling default).
    pub fn with_config(
        be: &'a NativeBackend,
        cfg: &EngineConfig,
    ) -> anyhow::Result<BatchDecoder<'a>> {
        anyhow::ensure!(cfg.max_batch >= 1, "batch decoder needs at least one slot");
        let mut model = ResolvedModel::new(be)?;
        if cfg.threads > 0 {
            // An explicit `--threads` on the engine config overrides the
            // backend's resolved count for this decoder's tile workers.
            model.threads = cfg.effective_threads();
        }
        // Size the persistent worker pool at engine start (first sizing
        // wins; later decoders just reuse it).
        crate::util::threadpool::init_global(model.threads);
        let cap = cfg.max_context.max(1);
        let (layers, d, heads) = (model.cfg.layers, model.cfg.d, model.cfg.heads);
        let kv = PagedKv::new(
            cfg.kv_bits,
            layers,
            d,
            heads,
            cfg.max_batch,
            cfg.page_positions(),
            cfg.pages_total(),
        );
        Ok(BatchDecoder {
            model,
            capacity: cap,
            default_sample: cfg.sample,
            slots: (0..cfg.max_batch).map(|_| None).collect(),
            kv,
            prefix: PrefixCache::new(),
            pending: VecDeque::new(),
            finished: Vec::new(),
            emitted: Vec::new(),
            admitted: Vec::new(),
            scratch: DecodeScratch::new(cap),
            stats: BatchStats::default(),
            births: 0,
            drift_sample: cfg.drift_sample,
        })
    }

    /// Queue a generation request decoding with the engine's default
    /// sampling (greedy unless the config set one). Requests that cannot
    /// fit the context cap or the page pool are rejected up front with a
    /// clear error instead of overflowing mid-decode; `max_new == 0`
    /// completes immediately.
    pub fn submit(&mut self, id: usize, prompt: &[u8], max_new: usize) -> anyhow::Result<()> {
        self.submit_sampled(id, prompt, max_new, None)
    }

    /// [`BatchDecoder::submit`] with explicit seeded sampling. `None`
    /// falls back to the engine default; a zero temperature keeps the
    /// bit-identical greedy path.
    pub fn submit_sampled(
        &mut self,
        id: usize,
        prompt: &[u8],
        max_new: usize,
        sample: Option<SampleCfg>,
    ) -> anyhow::Result<()> {
        self.submit_deadline(id, prompt, max_new, sample, None)
    }

    /// [`BatchDecoder::submit_sampled`] with an absolute deadline: past it
    /// the request retires with `finish_reason: "timeout"` at the next
    /// step boundary instead of burning slots and pool pages. Pass the
    /// *enqueue-time* instant plus the budget so queue wait counts.
    pub fn submit_deadline(
        &mut self,
        id: usize,
        prompt: &[u8],
        max_new: usize,
        sample: Option<SampleCfg>,
        deadline: Option<Instant>,
    ) -> anyhow::Result<()> {
        ensure_fits(
            self.capacity,
            self.kv.page_size(),
            self.kv.pages_total(),
            id,
            prompt.len(),
            max_new,
        )?;
        journal::record(EventKind::Enqueue, id, 0);
        if max_new == 0 {
            self.finished.push(GenOutput {
                id,
                tokens: Vec::new(),
                steps: 0,
                finish_reason: "length",
            });
            self.stats.completed += 1;
            journal::record(EventKind::Complete, id, 0);
            return Ok(());
        }
        let sample = sample.or(self.default_sample);
        self.pending.push_back(Pending::Fresh(GenRequest {
            id,
            prompt: prompt.to_vec(),
            max_new,
            sample,
            deadline,
        }));
        Ok(())
    }

    /// Stop decoding request `id`: drop it from the pending queue, or free
    /// its live KV slot (and pages) at this step boundary. Unknown ids
    /// (finished or never submitted) are a no-op. Cancelled requests
    /// produce no [`GenOutput`].
    pub fn cancel(&mut self, id: usize) -> CancelOutcome {
        if let Some(i) = self.pending.iter().position(|p| match p {
            Pending::Fresh(r) => r.id == id,
            Pending::Resume(a) => a.id == id,
        }) {
            let was_fresh = matches!(self.pending[i], Pending::Fresh(_));
            self.pending.remove(i);
            journal::record(EventKind::Evict, id, 0);
            return if was_fresh {
                CancelOutcome::Pending
            } else {
                // It had occupied a slot before preemption: count it like
                // a live eviction so the gauges stay consistent.
                self.stats.evicted += 1;
                CancelOutcome::Evicted
            };
        }
        for si in 0..self.slots.len() {
            if self.slots[si].as_ref().map(|a| a.id) == Some(id) {
                let generated = self.slots[si]
                    .as_ref()
                    .map(|a| (a.seq.len() - a.prompt_len) as u64)
                    .unwrap_or(0);
                self.slots[si] = None;
                self.kv.release_slot(si);
                self.stats.evicted += 1;
                journal::record(EventKind::Evict, id, generated);
                return CancelOutcome::Evicted;
            }
        }
        CancelOutcome::NotFound
    }

    /// Move queued requests into free slots (continuous admission). Fresh
    /// requests map prefix-cached pages first and start decoding after the
    /// shared span; resumed sequences re-map whatever prefix is still
    /// cached and replay the rest.
    fn admit(&mut self) {
        while !self.pending.is_empty() {
            let si = match self.slots.iter().position(Option::is_none) {
                Some(si) => si,
                None => break,
            };
            let entry = self.pending.pop_front().expect("non-empty pending queue");
            let active = match entry {
                Pending::Fresh(req) => {
                    self.admitted.push(req.id);
                    let shared = self.prefix.lookup(&req.prompt, self.kv.page_size());
                    let start = shared.len() * self.kv.page_size();
                    if !shared.is_empty() {
                        self.stats.prefix_hits += 1;
                        self.stats.prefix_tokens_reused += start;
                        self.kv.assign_shared(si, &shared);
                        journal::record(EventKind::PrefixHit, req.id, start as u64);
                    }
                    journal::record(EventKind::Admit, req.id, (req.prompt.len() - start) as u64);
                    self.births += 1;
                    Active {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        seq: req.prompt,
                        max_new: req.max_new,
                        pos: start,
                        steps: 0,
                        picker: TokenPicker::new(req.sample),
                        birth: self.births,
                        deadline: req.deadline,
                    }
                }
                Pending::Resume(mut a) => {
                    // The preemption released this sequence's pages; map
                    // whatever prefix survives in the cache and replay the
                    // already-chosen tokens from there. Keeps the original
                    // birth: a resumed request never gets younger.
                    let shared = self.prefix.lookup(&a.seq, self.kv.page_size());
                    if !shared.is_empty() {
                        self.kv.assign_shared(si, &shared);
                    }
                    a.pos = shared.len() * self.kv.page_size();
                    journal::record(EventKind::Resume, a.id, (a.seq.len() - a.pos) as u64);
                    a
                }
            };
            self.slots[si] = Some(active);
        }
    }

    /// Make sure every live slot's next write position has a page, oldest
    /// sequence first. A dry pool first evicts prefix-cache pages; if
    /// nothing frees, the youngest live sequence is preempted back to the
    /// queue (possibly the claimant itself) and the claim retried. The
    /// oldest sequence can always complete: [`ensure_fits`] bounded its
    /// total pages by the pool, and eviction + preemption return every
    /// other reference.
    fn claim_pages(&mut self) {
        let mut order: Vec<usize> =
            (0..self.slots.len()).filter(|&si| self.slots[si].is_some()).collect();
        order.sort_by_key(|&si| self.slots[si].as_ref().map(|a| a.birth).unwrap_or(u64::MAX));
        for si in order {
            loop {
                let block = match self.slots[si].as_ref() {
                    Some(a) => a.pos / self.kv.page_size(),
                    None => break, // preempted itself below
                };
                if self.kv.has_block(si, block) {
                    break;
                }
                if self.kv.try_claim(si) {
                    fault::check_hard(Site::PageClaim);
                    if journal::enabled() {
                        let id = self.slots[si].as_ref().map(|a| a.id).unwrap_or(0);
                        let pages = self.kv.table(si).len() as u64;
                        journal::record(EventKind::PageClaim, id, pages);
                    }
                    continue;
                }
                if self.prefix.evict_one(&mut self.kv) {
                    continue;
                }
                let victim = (0..self.slots.len())
                    .filter(|&v| self.slots[v].is_some())
                    .max_by_key(|&v| self.slots[v].as_ref().map(|a| a.birth).unwrap_or(0))
                    .expect("claimant slot is live");
                let a = self.slots[victim].take().expect("live victim");
                self.kv.release_slot(victim);
                journal::record(EventKind::Preempt, a.id, (a.seq.len() - a.prompt_len) as u64);
                self.pending.push_front(Pending::Resume(a));
                self.stats.preempted += 1;
                if victim == si {
                    break;
                }
            }
        }
    }

    /// Record one step's logits for a live slot: advance its position; at
    /// the sequence frontier pick the next token (emit, retire at
    /// `max_new`), otherwise this was preemption replay with nothing to
    /// choose.
    fn advance(&mut self, si: usize, logits: &[f32]) {
        let a = self.slots[si].as_mut().expect("live slot");
        a.pos += 1;
        a.steps += 1;
        if a.pos < a.seq.len() {
            return; // replaying tokens already chosen before a preemption
        }
        let t0 = profiler::start();
        let tok = a.picker.pick(logits);
        profiler::stop(Phase::TokenPick, t0);
        a.seq.push(tok);
        self.emitted.push((a.id, tok));
        if a.seq.len() - a.prompt_len >= a.max_new {
            let done = self.slots[si].take().expect("live slot");
            // Donate this sequence's full pages to the prefix cache before
            // releasing the slot's references (`done.pos` positions were
            // written; the final picked token was never fed).
            let table = self.kv.table(si).to_vec();
            self.prefix.register(&done.seq, &table, done.pos, self.kv.page_size(), &mut self.kv);
            self.kv.release_slot(si);
            let out = GenOutput {
                id: done.id,
                tokens: done.seq[done.prompt_len..].to_vec(),
                steps: done.steps,
                finish_reason: "length",
            };
            journal::record(EventKind::Complete, done.id, out.tokens.len() as u64);
            self.finished.push(out);
            self.stats.completed += 1;
        }
    }

    /// One continuous-batching decode step: admit pending requests, claim
    /// this step's KV pages (evicting or preempting if the pool is dry),
    /// advance every live sequence by one token through the unified fused
    /// step (one weight-tile unpack shared by all sequences), retire
    /// finished ones. Returns the number of sequences advanced; 0 means
    /// idle.
    pub fn step(&mut self) -> anyhow::Result<usize> {
        fault::check(Site::DecodeStep)?;
        self.emitted.clear();
        self.expire_deadlines();
        self.admit();
        self.claim_pages();
        let rows: Vec<StepRow> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(si, slot)| {
                slot.as_ref().map(|a| StepRow { token: a.seq[a.pos], pos: a.pos, slot: si })
            })
            .collect();
        if rows.is_empty() {
            return Ok(0);
        }
        let step_t0 = journal::enabled().then(journal::now_us);
        let logits = decode_rows(&self.model, &rows, &mut self.kv, &mut self.scratch);

        let b = rows.len();
        if let Some(t0) = step_t0 {
            journal::record_span(EventKind::Step, 0, t0, b as u64);
        }
        self.stats.steps += 1;
        self.stats.tokens += b;
        self.stats.peak_batch = self.stats.peak_batch.max(b);
        if self.drift_sample > 0 && self.stats.steps % self.drift_sample == 0 {
            self.drift_check(&rows, &logits);
        }
        for (r, row) in rows.iter().enumerate() {
            self.advance(row.slot, logits.row(r));
        }
        Ok(b)
    }

    /// Retire every queued or live request whose deadline has passed,
    /// before this step admits or decodes anything. Expired requests
    /// produce a [`GenOutput`] with `finish_reason: "timeout"` carrying
    /// whatever tokens they decoded; live victims free their slot and pool
    /// pages (no prefix donation — a half-written tail page must not enter
    /// the cache). Requests without deadlines never read the clock.
    fn expire_deadlines(&mut self) {
        let mut now: Option<Instant> = None;
        let mut expired = |deadline: Option<Instant>| match deadline {
            None => false,
            Some(d) => *now.get_or_insert_with(Instant::now) >= d,
        };
        let mut i = 0;
        while i < self.pending.len() {
            let hit = match &self.pending[i] {
                Pending::Fresh(r) => expired(r.deadline),
                Pending::Resume(a) => expired(a.deadline),
            };
            if !hit {
                i += 1;
                continue;
            }
            match self.pending.remove(i).expect("index in range") {
                Pending::Fresh(r) => {
                    journal::record(EventKind::Timeout, r.id, 0);
                    self.finished.push(GenOutput {
                        id: r.id,
                        tokens: Vec::new(),
                        steps: 0,
                        finish_reason: "timeout",
                    });
                }
                Pending::Resume(a) => {
                    journal::record(EventKind::Timeout, a.id, (a.seq.len() - a.prompt_len) as u64);
                    self.finished.push(GenOutput {
                        id: a.id,
                        tokens: a.seq[a.prompt_len..].to_vec(),
                        steps: a.steps,
                        finish_reason: "timeout",
                    });
                }
            }
            self.stats.timeouts += 1;
        }
        for si in 0..self.slots.len() {
            let hit = match self.slots[si].as_ref() {
                Some(a) => expired(a.deadline),
                None => false,
            };
            if !hit {
                continue;
            }
            let a = self.slots[si].take().expect("checked live");
            self.kv.release_slot(si);
            let generated = (a.seq.len() - a.prompt_len) as u64;
            journal::record(EventKind::Timeout, a.id, generated);
            self.finished.push(GenOutput {
                id: a.id,
                tokens: a.seq[a.prompt_len..].to_vec(),
                steps: a.steps,
                finish_reason: "timeout",
            });
            self.stats.timeouts += 1;
        }
    }

    /// Drift sentinel: recompute one sampled live row's logits through the
    /// forced-scalar kernel path against a read-only view of the live KV
    /// pool, and report the fast-vs-reference comparison into
    /// [`crate::obs::drift`]. Runs *before* [`BatchDecoder::advance`]
    /// mutates positions, so the recomputation sees exactly the state the
    /// fast pass decoded from; the no-op `write` guarantees tokens are
    /// bit-identical with the sentinel on or off.
    fn drift_check(&mut self, rows: &[StepRow], logits: &Matrix) {
        let r = (self.stats.steps / self.drift_sample) % rows.len();
        let prior = simd::forced();
        simd::force(Some(Isa::Scalar));
        let reference =
            decode_rows(&self.model, &rows[r..r + 1], &mut ReadOnlyKv(&self.kv), &mut self.scratch);
        simd::force(prior);
        drift::observe_rows(logits.row(r), reference.row(0));
    }

    /// Drive [`BatchDecoder::step`] until every submitted request finished;
    /// returns the outputs ordered by request id.
    pub fn run(&mut self) -> anyhow::Result<Vec<GenOutput>> {
        while self.step()? > 0 {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|o| o.id);
        Ok(out)
    }

    /// Engine counters accumulated so far.
    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Sequences currently occupying slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Fresh requests queued but not yet admitted (preempted sequences
    /// awaiting re-admission are *live work*, not queue depth).
    pub fn pending(&self) -> usize {
        self.pending.iter().filter(|p| matches!(p, Pending::Fresh(_))).count()
    }

    /// Per-sequence context cap (positions).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// KV-cache precision of the page pool.
    pub fn kv_bits(&self) -> KvBits {
        self.kv.kv_bits()
    }

    /// Resident bytes of one KV page (what the pool size multiplies).
    pub fn kv_bytes_per_page(&self) -> usize {
        self.kv.bytes_per_page()
    }

    /// Positions per KV page.
    pub fn page_size(&self) -> usize {
        self.kv.page_size()
    }

    /// Pool size in pages.
    pub fn pages_total(&self) -> usize {
        self.kv.pages_total()
    }

    /// Unclaimed pages right now.
    pub fn pages_free(&self) -> usize {
        self.kv.pages_free()
    }

    /// Full pages currently held by the prefix cache.
    pub fn prefix_cached_pages(&self) -> usize {
        self.prefix.len()
    }

    /// Drain finished outputs without waiting for the queue to empty
    /// (streaming consumers call this between steps).
    pub fn take_finished(&mut self) -> Vec<GenOutput> {
        std::mem::take(&mut self.finished)
    }

    /// `(request id, token)` pairs the most recent [`BatchDecoder::step`]
    /// emitted, in slot order. This is the per-step hook the streaming
    /// serving front-end ([`crate::serve`]) forwards into per-request
    /// channels so SSE bytes flush mid-decode; tokens also accumulate into
    /// the request's [`GenOutput`] unchanged. Preemption replay emits
    /// nothing — clients never see a token twice.
    pub fn emitted(&self) -> &[(usize, u8)] {
        &self.emitted
    }

    /// Request ids admitted into slots since the last drain. The serving
    /// engine calls this after each [`BatchDecoder::step`] to record
    /// queue-wait (enqueue → slot admission) per request.
    pub fn drain_admitted(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.admitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeDecoder;
    use crate::model::{ModelConfig, ModelWeights};

    fn pico_backend() -> NativeBackend {
        let cfg = ModelConfig::family("pico").unwrap();
        NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, 31))
    }

    #[test]
    fn idle_decoder_steps_zero() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 8).unwrap();
        assert_eq!(dec.step().unwrap(), 0);
        assert_eq!(dec.live(), 0);
        assert_eq!(dec.stats().steps, 0);
    }

    #[test]
    fn single_request_matches_native_decoder() {
        let nb = pico_backend();
        let expected = {
            let mut d = NativeDecoder::new(&nb, 32).unwrap();
            d.generate(b"hello", 6).unwrap()
        };
        let mut dec = BatchDecoder::new(&nb, 4, 32).unwrap();
        dec.submit(7, b"hello", 6).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, 7);
        assert_eq!(outs[0].tokens, expected);
        assert_eq!(outs[0].steps, 5 + 6 - 1);
    }

    #[test]
    fn more_requests_than_slots_recycles_and_completes_all() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        // Staggered lengths force retirement at different steps.
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            dec.submit(i, &[b'a' + i as u8, b'!'], *n).unwrap();
        }
        assert_eq!(dec.pending(), 5);
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 5);
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            assert_eq!(outs[i].id, i);
            assert_eq!(outs[i].tokens.len(), *n);
        }
        let stats = dec.stats();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.peak_batch, 2, "only two slots exist");
        // Σ per-sequence steps == Σ live batch sizes over all steps.
        let seq_steps: usize = outs.iter().map(|o| o.steps).sum();
        assert_eq!(stats.tokens, seq_steps);
    }

    #[test]
    fn drain_admitted_reports_each_id_once_at_slot_entry() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        dec.submit(10, b"ab", 2).unwrap();
        dec.submit(11, b"cd", 2).unwrap();
        dec.submit(12, b"ef", 2).unwrap(); // waits for a recycled slot
        assert!(dec.drain_admitted().is_empty(), "nothing admitted before a step");
        let mut seen = Vec::new();
        while dec.step().unwrap() > 0 {
            seen.extend(dec.drain_admitted());
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![10, 11, 12], "each request admitted exactly once");
        assert!(dec.drain_admitted().is_empty(), "drain clears the buffer");
    }

    #[test]
    fn zero_max_new_completes_immediately() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 8).unwrap();
        dec.submit(3, b"xy", 0).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(
            outs,
            vec![GenOutput { id: 3, tokens: Vec::new(), steps: 0, finish_reason: "length" }]
        );
    }

    #[test]
    fn submit_rejects_requests_beyond_capacity() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 4).unwrap();
        let err = dec.submit(0, b"too long for four", 2).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        let err = dec.submit(1, b"ab", 9).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        dec.submit(2, b"ab", 3).unwrap(); // 2 + 3 − 1 = 4 fits exactly
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn submit_rejects_requests_beyond_page_pool() {
        let nb = pico_backend();
        // Context cap admits 32 positions but the pool only holds 4 pages
        // of 4 = 16 — the page check must fire with a page-pool message.
        let cfg = EngineConfig::new()
            .with_max_batch(1)
            .with_max_context(32)
            .with_page_size(4)
            .with_pages(Some(4));
        let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();
        let err = dec.submit(0, b"a prompt of twenty chars", 8).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pages") && msg.contains("capacity"), "unclear page error: {msg}");
        dec.submit(1, b"short", 8).unwrap(); // 5 + 8 − 1 = 12 → 3 pages
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn emitted_tokens_stream_exactly_the_final_outputs() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 16).unwrap();
        dec.submit(0, b"ab", 3).unwrap();
        dec.submit(1, b"wxyz", 2).unwrap();
        dec.submit(2, b"q!", 4).unwrap(); // waits for a recycled slot
        let mut streamed: std::collections::BTreeMap<usize, Vec<u8>> = Default::default();
        while dec.step().unwrap() > 0 {
            for &(id, tok) in dec.emitted() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        assert!(dec.emitted().is_empty(), "idle step must clear emissions");
        let mut outs = dec.take_finished();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 3);
        for out in outs {
            assert_eq!(streamed[&out.id], out.tokens, "request {}", out.id);
        }
    }

    #[test]
    fn generate_batch_entry_point_matches_sequential_generate() {
        let nb = pico_backend();
        let prompts: Vec<&[u8]> = vec![b"one", b"second prompt", b"3rd"];
        let max_new = [5usize, 3, 8];
        let batched = nb.generate_batch(&prompts, &max_new).unwrap();
        for ((p, &n), got) in prompts.iter().zip(&max_new).zip(&batched) {
            let single = nb.generate(p, n).unwrap();
            assert_eq!(got, &single, "prompt {:?}", String::from_utf8_lossy(p));
        }
    }

    #[test]
    fn cancel_frees_slot_and_pending_and_skips_output() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 32).unwrap();
        dec.submit(0, b"live one", 20).unwrap();
        dec.submit(1, b"queued", 5).unwrap();
        dec.step().unwrap(); // request 0 occupies the only slot
        assert_eq!(dec.live(), 1);
        assert_eq!(dec.pending(), 1);
        assert_eq!(dec.cancel(1), CancelOutcome::Pending);
        assert_eq!(dec.pending(), 0);
        assert_eq!(dec.cancel(0), CancelOutcome::Evicted);
        assert_eq!(dec.live(), 0);
        assert_eq!(dec.cancel(42), CancelOutcome::NotFound);
        assert_eq!(dec.step().unwrap(), 0, "everything cancelled: idle");
        assert!(dec.take_finished().is_empty(), "cancelled requests produce no output");
        assert_eq!(dec.stats().evicted, 1, "only the live eviction counts");
        // The freed slot (and its pages) are reusable.
        dec.submit(2, b"after", 3).unwrap();
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn sampled_decode_is_seed_deterministic_across_placements() {
        let nb = pico_backend();
        // High temperature, no top-k cut: flat enough that two independent
        // seed streams cannot plausibly coincide for 8 straight tokens.
        let sample = Some(SampleCfg { temperature: 1.5, top_k: 0, seed: 2026 });
        let solo = {
            let mut dec = BatchDecoder::new(&nb, 1, 32).unwrap();
            dec.submit_sampled(0, b"sampled text", 8, sample).unwrap();
            dec.run().unwrap().remove(0).tokens
        };
        // Same request next to unrelated traffic, in a different slot order.
        let mut dec = BatchDecoder::new(&nb, 3, 32).unwrap();
        dec.submit(0, b"noise a", 6).unwrap();
        dec.submit_sampled(1, b"sampled text", 8, sample).unwrap();
        dec.submit_sampled(2, b"sampled text", 8, Some(SampleCfg { seed: 7, ..sample.unwrap() }))
            .unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs[1].tokens, solo, "seeded sampling must ignore batch placement");
        assert_ne!(outs[2].tokens, solo, "a different seed should diverge");
        // Greedy requests stay bit-identical to the unsampled path.
        let greedy = nb.generate(b"noise a", 6).unwrap();
        assert_eq!(outs[0].tokens, greedy);
    }

    #[test]
    fn engine_default_sampling_applies_when_request_has_none() {
        let nb = pico_backend();
        let sample = Some(SampleCfg { temperature: 1.5, top_k: 0, seed: 99 });
        let explicit = {
            let mut dec = BatchDecoder::new(&nb, 1, 32).unwrap();
            dec.submit_sampled(0, b"default sample", 8, sample).unwrap();
            dec.run().unwrap().remove(0).tokens
        };
        let cfg = EngineConfig::new().with_max_context(32).with_sample(sample);
        let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();
        dec.submit(0, b"default sample", 8).unwrap();
        assert_eq!(dec.run().unwrap().remove(0).tokens, explicit);
    }

    #[test]
    fn expired_deadline_retires_with_timeout_and_frees_the_slot() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 64).unwrap();
        // Already expired at submit: evicted from the queue at the first
        // step boundary, before ever occupying the slot.
        let past = Instant::now() - std::time::Duration::from_millis(1);
        dec.submit_deadline(0, b"never runs", 8, None, Some(past)).unwrap();
        assert_eq!(dec.step().unwrap(), 0, "expired request must not decode");
        let outs = dec.take_finished();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].finish_reason, "timeout");
        assert!(outs[0].tokens.is_empty());
        assert_eq!(dec.stats().timeouts, 1);

        // A live sequence keeps its partial tokens when the deadline hits
        // mid-decode, and the freed slot admits the next request. The
        // budget is generous enough that the decode steps below cannot
        // plausibly exhaust it before the explicit sleep does.
        let soon = Instant::now() + std::time::Duration::from_millis(300);
        dec.submit_deadline(1, b"partial", 50, None, Some(soon)).unwrap();
        for _ in 0..10 {
            assert_eq!(dec.step().unwrap(), 1);
        }
        assert_eq!(dec.live(), 1);
        std::thread::sleep(std::time::Duration::from_millis(320));
        assert_eq!(dec.step().unwrap(), 0, "expired live sequence is evicted, not decoded");
        let outs = dec.take_finished();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].finish_reason, "timeout");
        let n = outs[0].tokens.len();
        assert!((1..50).contains(&n), "partial tokens survive the timeout (got {n})");
        assert_eq!(dec.stats().timeouts, 2);
        dec.submit(2, b"after", 3).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].finish_reason, "length");
    }

    #[test]
    fn generous_deadline_changes_nothing() {
        let nb = pico_backend();
        let expected = nb.generate(b"hello", 6).unwrap();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        let far = Instant::now() + std::time::Duration::from_secs(3600);
        dec.submit_deadline(0, b"hello", 6, None, Some(far)).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs[0].tokens, expected, "an unexpired deadline must not perturb decode");
        assert_eq!(outs[0].finish_reason, "length");
        assert_eq!(dec.stats().timeouts, 0);
    }

    #[test]
    fn kv8_batched_decode_runs_and_shrinks_pages() {
        let nb = pico_backend();
        let cfg = EngineConfig::new().with_max_batch(2).with_max_context(32);
        let d32 = BatchDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::F32)).unwrap();
        let mut d8 = BatchDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::Q8)).unwrap();
        assert_eq!(d8.kv_bits(), KvBits::Q8);
        let ratio = d32.kv_bytes_per_page() as f64 / d8.kv_bytes_per_page() as f64;
        assert!(ratio >= 3.0, "kv8 page only {ratio:.2}x smaller");
        d8.submit(0, b"kv8 batched", 6).unwrap();
        d8.submit(1, b"second", 4).unwrap();
        let outs = d8.run().unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].tokens.len(), 6);
        assert_eq!(outs[1].tokens.len(), 4);
    }
}
