//! Continuous-batching generation engine over the native backend.
//!
//! [`BatchDecoder`] is the serving-scale sibling of
//! [`crate::backend::NativeDecoder`]: it maintains one KV-cache slot per
//! concurrent sequence, admits queued requests into free slots and retires
//! finished ones **between steps** (continuous batching, not static), and
//! advances all live sequences through the unified decode step
//! ([`crate::backend::fwd::decode_rows`]) — fused stacked-row matmuls, one
//! weight-tile unpack per step shared by every live sequence.
//!
//! Exactness contract: the batched and single-sequence decoders run the
//! *same* step function, and every kernel it touches keeps the
//! matvec ≡ shared bitwise contract per row — so greedy tokens at
//! `--kv-bits 32` match [`NativeDecoder`] bit-for-bit at any batch size
//! and any admission order. `--kv-bits 8` slots
//! ([`crate::backend::fwd::KvQ8`]) trade that bitwise guarantee for ~4×
//! smaller KV slots under tolerance gates.
//!
//! Per-request token selection goes through the core's
//! [`TokenPicker`] hook: greedy argmax by default, seeded
//! temperature/top-k sampling via [`BatchDecoder::submit_sampled`] —
//! reproducible across runs and batch placements because the RNG stream is
//! per request.
//!
//! [`NativeDecoder`]: crate::backend::NativeDecoder

use std::collections::VecDeque;

use crate::backend::fwd::{
    decode_rows, DecodeScratch, KvBits, KvCache, KvStore, SampleCfg, StepRow, TokenPicker,
};
use crate::backend::native::{NativeBackend, ResolvedModel};
use crate::obs::profiler::{self, Phase};

/// One generation request queued for slot admission.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Caller-chosen identifier; outputs are reported against it.
    pub id: usize,
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Seeded sampling parameters; `None` decodes greedily.
    pub sample: Option<SampleCfg>,
}

/// Validate that a request fits one preallocated KV slot. Shared by
/// [`BatchDecoder::submit`] and the HTTP admission check in
/// [`crate::serve`], so the serving front-end rejects oversized requests
/// with exactly the same KV-capacity text the decoder itself uses.
pub fn ensure_fits(
    capacity: usize,
    id: usize,
    prompt_len: usize,
    max_new: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(prompt_len > 0, "request {id}: empty prompt");
    // Saturating: a request with max_new near usize::MAX must hit the
    // capacity error below, not wrap past it (this guards a network input).
    let needed = prompt_len.saturating_add(max_new.saturating_sub(1));
    anyhow::ensure!(
        needed <= capacity,
        "request {id}: prompt of {prompt_len} tokens + {max_new} generated needs {needed} KV \
         positions but each slot preallocated {capacity} (KV capacity); raise the decoder \
         capacity or shorten the request"
    );
    Ok(())
}

/// A finished generation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenOutput {
    pub id: usize,
    pub tokens: Vec<u8>,
    /// Decode steps this sequence was live for (prompt + generated − 1).
    pub steps: usize,
}

/// Aggregate engine counters for throughput reporting.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    /// Fused decode steps executed.
    pub steps: usize,
    /// Sequence-tokens processed (Σ live batch size over all steps).
    pub tokens: usize,
    /// Largest live batch observed in one step.
    pub peak_batch: usize,
    /// Requests completed.
    pub completed: usize,
    /// Live sequences evicted by [`BatchDecoder::cancel`] before finishing.
    pub evicted: usize,
}

/// What [`BatchDecoder::cancel`] found for the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Removed from the pending queue before ever occupying a slot.
    Pending,
    /// Evicted from a live KV slot (freed at this step boundary).
    Evicted,
    /// Unknown id (already finished or never submitted).
    NotFound,
}

/// A sequence occupying a slot: its request plus decode progress.
struct Active {
    id: usize,
    prompt: Vec<u8>,
    /// Tokens fed into the model so far (prompt first, then generated).
    fed: usize,
    out: Vec<u8>,
    max_new: usize,
    /// Next KV position to write == this sequence's context length.
    pos: usize,
    /// Token-selection hook (greedy or seeded sampling).
    picker: TokenPicker,
}

impl Active {
    /// The token this sequence feeds on the next step: the next prompt
    /// token during prefill, the last emitted token afterwards.
    fn next_input(&self) -> u8 {
        if self.fed < self.prompt.len() {
            self.prompt[self.fed]
        } else {
            *self.out.last().expect("generated token")
        }
    }
}

/// Continuous-batching decoder over a [`NativeBackend`].
///
/// ```text
/// submit(..) → pending ─admit─▶ slots (≤ max_slots live) ─retire─▶ finished
///                                  │ step(): one fused decode_rows over
///                                  ▼         all live rows
/// ```
///
/// [`BatchDecoder::step`] admits pending requests into free slots, advances
/// every live sequence by one token through the unified decode step, and
/// retires sequences that produced their `max_new`-th token — freeing the
/// slot for the next pending request on the following step.
/// [`BatchDecoder::cancel`] evicts a live sequence at the step boundary
/// (the serving front-end calls it when a client disconnects mid-stream).
pub struct BatchDecoder<'a> {
    model: ResolvedModel<'a>,
    /// Per-slot KV capacity (positions).
    capacity: usize,
    slots: Vec<Option<Active>>,
    caches: Vec<KvCache>,
    pending: VecDeque<GenRequest>,
    finished: Vec<GenOutput>,
    /// `(request id, token)` pairs emitted by the most recent step, in slot
    /// order — the hook streaming consumers read between steps.
    emitted: Vec<(usize, u8)>,
    /// Request ids moved from the pending queue into a slot since the last
    /// [`BatchDecoder::drain_admitted`] — the serving engine reads these to
    /// stamp queue-wait at the moment of admission.
    admitted: Vec<usize>,
    scratch: DecodeScratch,
    stats: BatchStats,
}

impl<'a> BatchDecoder<'a> {
    /// Resolve the backend's weights and preallocate `max_slots` KV-cache
    /// slots of `capacity` positions each, at the backend's configured
    /// `--kv-bits` precision.
    pub fn new(
        be: &'a NativeBackend,
        max_slots: usize,
        capacity: usize,
    ) -> anyhow::Result<BatchDecoder<'a>> {
        BatchDecoder::new_with_kv(be, max_slots, capacity, be.kv_bits())
    }

    /// [`BatchDecoder::new`] with an explicit KV-cache precision.
    pub fn new_with_kv(
        be: &'a NativeBackend,
        max_slots: usize,
        capacity: usize,
        kv_bits: KvBits,
    ) -> anyhow::Result<BatchDecoder<'a>> {
        anyhow::ensure!(max_slots >= 1, "batch decoder needs at least one slot");
        let model = ResolvedModel::new(be)?;
        let cap = capacity.max(1);
        let (layers, d, heads) = (model.cfg.layers, model.cfg.d, model.cfg.heads);
        let caches: Vec<KvCache> =
            (0..max_slots).map(|_| KvCache::new(kv_bits, layers, cap, d, heads)).collect();
        Ok(BatchDecoder {
            model,
            capacity: cap,
            slots: (0..max_slots).map(|_| None).collect(),
            caches,
            pending: VecDeque::new(),
            finished: Vec::new(),
            emitted: Vec::new(),
            admitted: Vec::new(),
            scratch: DecodeScratch::new(cap),
            stats: BatchStats::default(),
        })
    }

    /// Queue a greedy generation request. Requests that cannot fit a KV
    /// slot are rejected up front with a clear error instead of overflowing
    /// the cache mid-decode; `max_new == 0` completes immediately.
    pub fn submit(&mut self, id: usize, prompt: &[u8], max_new: usize) -> anyhow::Result<()> {
        self.submit_sampled(id, prompt, max_new, None)
    }

    /// [`BatchDecoder::submit`] with optional seeded sampling. `None` (or a
    /// zero temperature) keeps the bit-identical greedy path.
    pub fn submit_sampled(
        &mut self,
        id: usize,
        prompt: &[u8],
        max_new: usize,
        sample: Option<SampleCfg>,
    ) -> anyhow::Result<()> {
        ensure_fits(self.capacity, id, prompt.len(), max_new)?;
        if max_new == 0 {
            self.finished.push(GenOutput { id, tokens: Vec::new(), steps: 0 });
            self.stats.completed += 1;
            return Ok(());
        }
        self.pending.push_back(GenRequest { id, prompt: prompt.to_vec(), max_new, sample });
        Ok(())
    }

    /// Stop decoding request `id`: drop it from the pending queue, or free
    /// its live KV slot at this step boundary. Unknown ids (finished or
    /// never submitted) are a no-op. Cancelled requests produce no
    /// [`GenOutput`].
    pub fn cancel(&mut self, id: usize) -> CancelOutcome {
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            self.pending.remove(i);
            return CancelOutcome::Pending;
        }
        for slot in self.slots.iter_mut() {
            if slot.as_ref().map(|a| a.id) == Some(id) {
                *slot = None;
                self.stats.evicted += 1;
                return CancelOutcome::Evicted;
            }
        }
        CancelOutcome::NotFound
    }

    /// Move queued requests into free slots (continuous admission).
    fn admit(&mut self) {
        while !self.pending.is_empty() {
            let free = self.slots.iter().position(Option::is_none);
            let si = match free {
                Some(si) => si,
                None => break,
            };
            let req = self.pending.pop_front().expect("non-empty pending queue");
            self.admitted.push(req.id);
            self.slots[si] = Some(Active {
                id: req.id,
                prompt: req.prompt,
                fed: 0,
                out: Vec::new(),
                max_new: req.max_new,
                pos: 0,
                picker: TokenPicker::new(req.sample),
            });
        }
    }

    /// Record one step's logits for a live slot: advance its position,
    /// emit through the token picker once the prompt is consumed, retire
    /// when done.
    fn advance(&mut self, si: usize, logits: &[f32]) {
        let a = self.slots[si].as_mut().expect("live slot");
        a.pos += 1;
        a.fed += 1;
        if a.fed >= a.prompt.len() {
            let t0 = profiler::start();
            let tok = a.picker.pick(logits);
            profiler::stop(Phase::TokenPick, t0);
            a.out.push(tok);
            self.emitted.push((a.id, tok));
            if a.out.len() >= a.max_new {
                let done = self.slots[si].take().expect("live slot");
                let out = GenOutput { id: done.id, tokens: done.out, steps: done.fed };
                self.finished.push(out);
                self.stats.completed += 1;
            }
        }
    }

    /// One continuous-batching decode step: admit pending requests, advance
    /// every live sequence by one token through the unified fused step
    /// (one weight-tile unpack shared by all sequences), retire finished
    /// ones. Returns the number of sequences advanced; 0 means idle.
    pub fn step(&mut self) -> anyhow::Result<usize> {
        self.emitted.clear();
        self.admit();
        let rows: Vec<StepRow> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(si, slot)| {
                slot.as_ref().map(|a| StepRow { token: a.next_input(), pos: a.pos, slot: si })
            })
            .collect();
        if rows.is_empty() {
            return Ok(0);
        }
        let logits = decode_rows(&self.model, &rows, &mut self.caches, &mut self.scratch);

        let b = rows.len();
        self.stats.steps += 1;
        self.stats.tokens += b;
        self.stats.peak_batch = self.stats.peak_batch.max(b);
        for (r, row) in rows.iter().enumerate() {
            self.advance(row.slot, logits.row(r));
        }
        Ok(b)
    }

    /// Drive [`BatchDecoder::step`] until every submitted request finished;
    /// returns the outputs ordered by request id.
    pub fn run(&mut self) -> anyhow::Result<Vec<GenOutput>> {
        while self.step()? > 0 {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|o| o.id);
        Ok(out)
    }

    /// Engine counters accumulated so far.
    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Sequences currently occupying slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Per-slot KV capacity (positions).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// KV-cache precision of this decoder's slots.
    pub fn kv_bits(&self) -> KvBits {
        self.caches.first().map(|c| c.kv_bits()).unwrap_or(KvBits::F32)
    }

    /// Resident bytes of one KV slot (what `--max-batch` multiplies).
    pub fn kv_bytes_per_slot(&self) -> usize {
        self.caches.first().map(|c| c.bytes()).unwrap_or(0)
    }

    /// Drain finished outputs without waiting for the queue to empty
    /// (streaming consumers call this between steps).
    pub fn take_finished(&mut self) -> Vec<GenOutput> {
        std::mem::take(&mut self.finished)
    }

    /// `(request id, token)` pairs the most recent [`BatchDecoder::step`]
    /// emitted, in slot order. This is the per-step hook the streaming
    /// serving front-end ([`crate::serve`]) forwards into per-request
    /// channels so SSE bytes flush mid-decode; tokens also accumulate into
    /// the request's [`GenOutput`] unchanged.
    pub fn emitted(&self) -> &[(usize, u8)] {
        &self.emitted
    }

    /// Request ids admitted into slots since the last drain. The serving
    /// engine calls this after each [`BatchDecoder::step`] to record
    /// queue-wait (enqueue → slot admission) per request.
    pub fn drain_admitted(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.admitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeDecoder;
    use crate::model::{ModelConfig, ModelWeights};

    fn pico_backend() -> NativeBackend {
        let cfg = ModelConfig::family("pico").unwrap();
        NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, 31))
    }

    #[test]
    fn idle_decoder_steps_zero() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 8).unwrap();
        assert_eq!(dec.step().unwrap(), 0);
        assert_eq!(dec.live(), 0);
        assert_eq!(dec.stats().steps, 0);
    }

    #[test]
    fn single_request_matches_native_decoder() {
        let nb = pico_backend();
        let expected = {
            let mut d = NativeDecoder::new(&nb, 32).unwrap();
            d.generate(b"hello", 6).unwrap()
        };
        let mut dec = BatchDecoder::new(&nb, 4, 32).unwrap();
        dec.submit(7, b"hello", 6).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, 7);
        assert_eq!(outs[0].tokens, expected);
        assert_eq!(outs[0].steps, 5 + 6 - 1);
    }

    #[test]
    fn more_requests_than_slots_recycles_and_completes_all() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        // Staggered lengths force retirement at different steps.
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            dec.submit(i, &[b'a' + i as u8, b'!'], *n).unwrap();
        }
        assert_eq!(dec.pending(), 5);
        let outs = dec.run().unwrap();
        assert_eq!(outs.len(), 5);
        for (i, n) in [3usize, 7, 5, 2, 6].iter().enumerate() {
            assert_eq!(outs[i].id, i);
            assert_eq!(outs[i].tokens.len(), *n);
        }
        let stats = dec.stats();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.peak_batch, 2, "only two slots exist");
        // Σ per-sequence steps == Σ live batch sizes over all steps.
        let seq_steps: usize = outs.iter().map(|o| o.steps).sum();
        assert_eq!(stats.tokens, seq_steps);
    }

    #[test]
    fn drain_admitted_reports_each_id_once_at_slot_entry() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 32).unwrap();
        dec.submit(10, b"ab", 2).unwrap();
        dec.submit(11, b"cd", 2).unwrap();
        dec.submit(12, b"ef", 2).unwrap(); // waits for a recycled slot
        assert!(dec.drain_admitted().is_empty(), "nothing admitted before a step");
        let mut seen = Vec::new();
        while dec.step().unwrap() > 0 {
            seen.extend(dec.drain_admitted());
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![10, 11, 12], "each request admitted exactly once");
        assert!(dec.drain_admitted().is_empty(), "drain clears the buffer");
    }

    #[test]
    fn zero_max_new_completes_immediately() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 8).unwrap();
        dec.submit(3, b"xy", 0).unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs, vec![GenOutput { id: 3, tokens: Vec::new(), steps: 0 }]);
    }

    #[test]
    fn submit_rejects_requests_beyond_slot_capacity() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 4).unwrap();
        let err = dec.submit(0, b"too long for four", 2).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        let err = dec.submit(1, b"ab", 9).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        dec.submit(2, b"ab", 3).unwrap(); // 2 + 3 − 1 = 4 fits exactly
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn emitted_tokens_stream_exactly_the_final_outputs() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 2, 16).unwrap();
        dec.submit(0, b"ab", 3).unwrap();
        dec.submit(1, b"wxyz", 2).unwrap();
        dec.submit(2, b"q!", 4).unwrap(); // waits for a recycled slot
        let mut streamed: std::collections::BTreeMap<usize, Vec<u8>> = Default::default();
        while dec.step().unwrap() > 0 {
            for &(id, tok) in dec.emitted() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        assert!(dec.emitted().is_empty(), "idle step must clear emissions");
        let mut outs = dec.take_finished();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 3);
        for out in outs {
            assert_eq!(streamed[&out.id], out.tokens, "request {}", out.id);
        }
    }

    #[test]
    fn generate_batch_entry_point_matches_sequential_generate() {
        let nb = pico_backend();
        let prompts: Vec<&[u8]> = vec![b"one", b"second prompt", b"3rd"];
        let max_new = [5usize, 3, 8];
        let batched = nb.generate_batch(&prompts, &max_new).unwrap();
        for ((p, &n), got) in prompts.iter().zip(&max_new).zip(&batched) {
            let single = nb.generate(p, n).unwrap();
            assert_eq!(got, &single, "prompt {:?}", String::from_utf8_lossy(p));
        }
    }

    #[test]
    fn cancel_frees_slot_and_pending_and_skips_output() {
        let nb = pico_backend();
        let mut dec = BatchDecoder::new(&nb, 1, 32).unwrap();
        dec.submit(0, b"live one", 20).unwrap();
        dec.submit(1, b"queued", 5).unwrap();
        dec.step().unwrap(); // request 0 occupies the only slot
        assert_eq!(dec.live(), 1);
        assert_eq!(dec.pending(), 1);
        assert_eq!(dec.cancel(1), CancelOutcome::Pending);
        assert_eq!(dec.pending(), 0);
        assert_eq!(dec.cancel(0), CancelOutcome::Evicted);
        assert_eq!(dec.live(), 0);
        assert_eq!(dec.cancel(42), CancelOutcome::NotFound);
        assert_eq!(dec.step().unwrap(), 0, "everything cancelled: idle");
        assert!(dec.take_finished().is_empty(), "cancelled requests produce no output");
        assert_eq!(dec.stats().evicted, 1, "only the live eviction counts");
        // The freed slot is reusable.
        dec.submit(2, b"after", 3).unwrap();
        assert_eq!(dec.run().unwrap().len(), 1);
    }

    #[test]
    fn sampled_decode_is_seed_deterministic_across_placements() {
        let nb = pico_backend();
        // High temperature, no top-k cut: flat enough that two independent
        // seed streams cannot plausibly coincide for 8 straight tokens.
        let sample = Some(SampleCfg { temperature: 1.5, top_k: 0, seed: 2026 });
        let solo = {
            let mut dec = BatchDecoder::new(&nb, 1, 32).unwrap();
            dec.submit_sampled(0, b"sampled text", 8, sample).unwrap();
            dec.run().unwrap().remove(0).tokens
        };
        // Same request next to unrelated traffic, in a different slot order.
        let mut dec = BatchDecoder::new(&nb, 3, 32).unwrap();
        dec.submit(0, b"noise a", 6).unwrap();
        dec.submit_sampled(1, b"sampled text", 8, sample).unwrap();
        dec.submit_sampled(2, b"sampled text", 8, Some(SampleCfg { seed: 7, ..sample.unwrap() }))
            .unwrap();
        let outs = dec.run().unwrap();
        assert_eq!(outs[1].tokens, solo, "seeded sampling must ignore batch placement");
        assert_ne!(outs[2].tokens, solo, "a different seed should diverge");
        // Greedy requests stay bit-identical to the unsampled path.
        let greedy = nb.generate(b"noise a", 6).unwrap();
        assert_eq!(outs[0].tokens, greedy);
    }

    #[test]
    fn kv8_batched_decode_runs_and_shrinks_slots() {
        let nb = pico_backend();
        let d32 = BatchDecoder::new_with_kv(&nb, 2, 32, KvBits::F32).unwrap();
        let mut d8 = BatchDecoder::new_with_kv(&nb, 2, 32, KvBits::Q8).unwrap();
        assert_eq!(d8.kv_bits(), KvBits::Q8);
        let ratio = d32.kv_bytes_per_slot() as f64 / d8.kv_bytes_per_slot() as f64;
        assert!(ratio >= 3.0, "kv8 slot only {ratio:.2}x smaller");
        d8.submit(0, b"kv8 batched", 6).unwrap();
        d8.submit(1, b"second", 4).unwrap();
        let outs = d8.run().unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].tokens.len(), 6);
        assert_eq!(outs[1].tokens.len(), 4);
    }
}
