//! Quantization diagnostics: the quantities behind Figs. 2c, 3, and 7.

use super::QuantizedLinear;
use crate::tensor::{stats, Matrix};

/// Relative matrix (weight) reconstruction error:
/// `‖W − Ŵ‖_F / ‖W‖_F` (Fig. 3a's quantity, reported as a delta vs RTN).
pub fn weight_recon_error(w: &Matrix, q: &QuantizedLinear) -> f64 {
    let eff = q.effective_weight();
    rel_fro(w, &eff)
}

/// Relative activation (output) reconstruction error on inputs `x`:
/// `‖X·Wᵀ − X·Ŵᵀ‖_F / ‖X·Wᵀ‖_F` (Fig. 3b).
pub fn activation_recon_error(x: &Matrix, w: &Matrix, q: &QuantizedLinear) -> f64 {
    let y = x.matmul_nt(w);
    let y_hat = x.matmul_nt(&q.effective_weight());
    rel_fro(&y, &y_hat)
}

pub(crate) fn rel_fro(a: &Matrix, b: &Matrix) -> f64 {
    let num: f64 = a.data.iter().zip(&b.data).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = a.data.iter().map(|&x| (x as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

/// Mean row-wise kurtosis of the matrix a quantizer actually rounds
/// (Fig. 2c / Fig. 7): for dual-scale methods that is the normalized matrix.
pub fn rounded_space_kurtosis(w: &Matrix, q: &QuantizedLinear) -> f64 {
    // Reconstruct the rounded-space matrix: undo s/t from the effective W.
    // Simpler and exact: the codes themselves are the rounded values; use
    // the normalized residual space instead — divide W by the layer scales.
    let mut ws = w.clone();
    if let Some(t) = &q.col_scale {
        ws.div_cols(t);
    }
    // Row scales are folded into group scales; dividing per group recovers
    // the per-row normalization closely enough for the kurtosis diagnostic.
    stats::mean_row_kurtosis(&ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{quantize_matrix, Method, QuantConfig};
    use crate::tensor::Rng;

    #[test]
    fn fig3_shape_hadamard_better_matrix_sinq_better_activation() {
        // The paper's Fig. 3 claim, on weights whose column structure
        // mirrors input magnitudes: Hadamard wins matrix MSE, SINQ wins
        // activation MSE.
        let w = llm_like(64, 128, 141);
        // Inputs anti-correlated with column std (the trained-model relation).
        let col_stds = stats::col_stds(&w);
        let mut rng = Rng::new(142);
        let mut x = Matrix::from_fn(64, 128, |_, _| rng.normal_f32(0.0, 1.0));
        let t: Vec<f32> = col_stds.iter().map(|&s| (0.02 / s.max(1e-6)) as f32).collect();
        x.scale_cols(&t);

        let q_sinq = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 3), None).unwrap();
        let q_had =
            quantize_matrix(&w, &QuantConfig::new(Method::HadamardRtn, 3), None).unwrap();

        let m_sinq = weight_recon_error(&w, &q_sinq);
        let m_had = weight_recon_error(&w, &q_had);
        let a_sinq = activation_recon_error(&x, &w, &q_sinq);
        let a_had = activation_recon_error(&x, &w, &q_had);

        assert!(m_had < m_sinq, "hadamard matrix {m_had:.4} vs sinq {m_sinq:.4}");
        assert!(a_sinq < a_had, "sinq act {a_sinq:.4} vs hadamard {a_had:.4}");
    }

    #[test]
    fn errors_are_relative() {
        let w = llm_like(16, 64, 143);
        let q = quantize_matrix(&w, &QuantConfig::new(Method::Rtn, 8), None).unwrap();
        let e = weight_recon_error(&w, &q);
        assert!(e < 0.01, "8-bit rel error {e}");
        let q2 = quantize_matrix(&w, &QuantConfig::new(Method::Rtn, 2), None).unwrap();
        assert!(weight_recon_error(&w, &q2) > e * 10.0);
    }
}
