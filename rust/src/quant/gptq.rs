//! GPTQ — Hessian-based post-training quantization (Frantar et al., 2022).
//!
//! Quantizes weight columns sequentially; after each column the remaining
//! (not yet quantized) columns absorb the rounding error, weighted by the
//! inverse Hessian `H⁻¹` of the layer's input covariance
//! `H = 2·XᵀX + λ·mean(diag)·I`. Follows the reference implementation's
//! Cholesky formulation: work with the upper Cholesky factor `U` of `H⁻¹`
//! (so `H⁻¹ = U·Uᵀ`), use `d_j = U[j,j]` and propagate
//! `W[:, j+1:] −= err ⊗ U[j, j+1:] / d_j`.
//!
//! `Hadamard + GPTQ` (Table 2/4 baseline) rotates the input space first and
//! rotates the calibration activations to match.

use super::{apply_aux_precision, hadamard, rtn, Calibration, QuantConfig, QuantizedLinear};
use crate::tensor::linalg;
use crate::tensor::Matrix;

/// Build the damped Hessian `2·XᵀX/n + λ·mean(diag)·I`.
fn hessian(x: &Matrix, damp: f32) -> Matrix {
    let m = x.cols;
    let mut h = Matrix::zeros(m, m);
    // H = Xᵀ·X accumulated row-by-row (n small in calibration).
    for r in 0..x.rows {
        let row = x.row(r);
        for a in 0..m {
            let va = row[a];
            if va == 0.0 {
                continue;
            }
            let hrow = &mut h.data[a * m..(a + 1) * m];
            for (hv, &vb) in hrow.iter_mut().zip(row.iter()) {
                *hv += 2.0 * va * vb / x.rows as f32;
            }
        }
    }
    let mean_diag = (0..m).map(|i| h.at(i, i) as f64).sum::<f64>() / m as f64;
    let lambda = (damp as f64 * mean_diag).max(1e-8) as f32;
    for i in 0..m {
        *h.at_mut(i, i) += lambda;
    }
    h
}

/// GPTQ quantization. `rotate` applies the Hadamard transform to both the
/// weight input space and the calibration activations first.
pub fn quantize(
    w: &Matrix,
    cfg: &QuantConfig,
    calib: &Calibration,
    rotate: bool,
) -> QuantizedLinear {
    let (mut work, x);
    if rotate {
        let mut wr = w.clone();
        hadamard::rotate_cols(&mut wr);
        let mut xr = calib.x.clone();
        hadamard::rotate_cols(&mut xr);
        work = wr;
        x = xr;
    } else {
        work = w.clone();
        x = calib.x.clone();
    }

    let h = hessian(&x, cfg.gptq_damp);
    // Upper Cholesky factor of H⁻¹. If H is ill-conditioned fall back to a
    // more strongly damped version rather than aborting the layer.
    let u = linalg::cholesky_inverse_upper(&h)
        .or_else(|| linalg::cholesky_inverse_upper(&hessian(&x, cfg.gptq_damp * 100.0)))
        .expect("GPTQ Hessian not invertible even with heavy damping");

    let g = cfg.group_size;
    let n_groups = work.cols.div_ceil(g);
    let maxq = (cfg.grid.size() - 1) as f32;
    let mut codes = vec![0u8; work.rows * work.cols];
    let mut scales = Matrix::zeros(work.rows, n_groups);
    let mut shifts = Matrix::zeros(work.rows, n_groups);

    let cols = work.cols;
    for j in 0..cols {
        let gi = j / g;
        if j % g == 0 {
            // (Re-)fit scale/shift per row from the *current* (error-
            // compensated) values of this group, exactly like reference
            // GPTQ with `groupsize`.
            let j1 = (j + g).min(cols);
            for i in 0..work.rows {
                let gq = rtn::quantize_group(&work.row(i)[j..j1], &cfg.grid, cfg.shift);
                *scales.at_mut(i, gi) = gq.scale;
                *shifts.at_mut(i, gi) = gq.shift;
            }
        }
        let d = u.at(j, j);
        for i in 0..work.rows {
            let s = scales.at(i, gi);
            let z = shifts.at(i, gi);
            let v = work.at(i, j);
            let q = (v / s - z).round().clamp(0.0, maxq);
            codes[i * cols + j] = q as u8;
            let dq = s * (q + z);
            let err = (v - dq) / d;
            // Propagate into remaining columns.
            let urow = u.row(j);
            let wrow = work.row_mut(i);
            for jj in j + 1..cols {
                wrow[jj] -= err * urow[jj];
            }
        }
    }

    apply_aux_precision(&mut scales, cfg.aux);
    apply_aux_precision(&mut shifts, cfg.aux);
    QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: g,
        grid: cfg.grid.clone(),
        codes,
        scales,
        shifts: Some(shifts),
        col_scale: None,
        hadamard: rotate,
        hadamard_out: false,
        pair_codebook: None,
        aux: cfg.aux,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{Method, QuantConfig};
    use crate::tensor::Rng;

    fn gaussian_calib(cols: usize, n: usize, seed: u64) -> Calibration {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::from_fn(n, cols, |_, _| rng.normal_f32(0.0, 1.0));
        // Column-correlated inputs make the Hessian non-trivial.
        let t: Vec<f32> = (0..cols).map(|_| 0.3 + 2.0 * rng.uniform() as f32).collect();
        x.scale_cols(&t);
        Calibration::from_activations(x)
    }

    fn act_err(x: &Matrix, w: &Matrix, q: &QuantizedLinear) -> f64 {
        let y = x.matmul_nt(w);
        let yh = x.matmul_nt(&q.effective_weight());
        y.data.iter().zip(&yh.data).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let w = llm_like(32, 64, 101);
        let calib = gaussian_calib(64, 128, 102);
        let cfg = QuantConfig::new(Method::Gptq, 3);
        let q_gptq = quantize(&w, &cfg, &calib, false);
        let q_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 3));
        let (e_g, e_r) = (act_err(&calib.x, &w, &q_gptq), act_err(&calib.x, &w, &q_rtn));
        assert!(e_g < e_r, "gptq {e_g:.4e} vs rtn {e_r:.4e}");
    }

    #[test]
    fn hadamard_gptq_recovers_original_space() {
        let w = llm_like(16, 64, 103);
        let calib = gaussian_calib(64, 96, 104);
        let cfg = QuantConfig::new(Method::HadamardGptq, 8);
        let q = quantize(&w, &cfg, &calib, true);
        assert!(q.hadamard);
        let rel = q.effective_weight().dist(&w) / w.dist(&Matrix::zeros(16, 64));
        assert!(rel < 0.05, "8-bit hadamard+gptq rel err {rel}");
    }

    #[test]
    fn hessian_is_spd_and_scaled() {
        let calib = gaussian_calib(32, 64, 105);
        let h = hessian(&calib.x, 0.01);
        assert!(linalg::cholesky(&h).is_some(), "hessian must be SPD");
        // Diagonal dominated by 2·E[x²].
        for i in 0..32 {
            assert!(h.at(i, i) > 0.0);
        }
    }

    #[test]
    fn group_boundaries_respected() {
        let w = llm_like(8, 96, 106); // 96 = 64 + 32 ragged final group
        let calib = gaussian_calib(96, 64, 107);
        let q = quantize(&w, &QuantConfig::new(Method::Gptq, 4), &calib, false);
        assert_eq!(q.n_groups(), 2);
        assert!(q.codes.iter().all(|&c| c < 16));
    }
}
