//! Shared test fixtures for the quantizer zoo.

use crate::tensor::{Matrix, Rng};

/// A synthetic "LLM-like" weight matrix: heavy-tailed entries with structured
/// row/column scale variation plus hard outliers — the statistics Adam
/// training produces and that the paper's method exploits.
pub(crate) fn llm_like(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let row_s: Vec<f32> = (0..rows).map(|_| 0.5 + rng.uniform() as f32 * 2.0).collect();
    let col_s: Vec<f32> = (0..cols).map(|_| 0.3 + rng.uniform() as f32 * 3.0).collect();
    let mut w = Matrix::from_fn(rows, cols, |_, _| 0.02 * rng.student_t(4.0) as f32);
    w.scale_rows(&row_s);
    w.scale_cols(&col_s);
    // A few hard outliers.
    for _ in 0..(rows * cols / 256).max(1) {
        let i = rng.below(rows);
        let j = rng.below(cols);
        *w.at_mut(i, j) *= 8.0;
    }
    w
}

/// Weights of a single linear layer trained to Adam stationarity on a noisy
/// target with per-channel input scales `s_x` (the paper's Fig. 2b setting).
/// Returns (W, s_x). The emergent relation is `σ_col(W) ∝ 1/sqrt(s_x)`.
pub(crate) fn adam_stationary(nout: usize, nin: usize, steps: usize, seed: u64) -> (Matrix, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let bs = 12usize;
    let s_x: Vec<f32> =
        (0..nin).map(|_| (0.1f64 + rng.laplace(0.6).abs().exp()) as f32 * 0.3).collect();
    let mut w = Matrix::randn(nout, nin, 0.01, &mut rng);
    let (mut m, mut v) = (Matrix::zeros(nout, nin), Matrix::zeros(nout, nin));
    let (b1, b2, lr, eps) = (0.9f32, 0.999f32, 2e-3f32, 1e-8f32);
    for t in 1..=steps {
        let mut x = Matrix::from_fn(bs, nin, |_, _| rng.normal_f32(0.0, 1.0));
        x.scale_cols(&s_x);
        let yh = x.matmul_nt(&w);
        // Pure-noise target: residual = prediction + fresh gaussian noise.
        let mut d = Matrix::zeros(bs, nout);
        for i in 0..bs * nout {
            d.data[i] = yh.data[i] + rng.normal_f32(0.0, 1.0);
        }
        let g = d.transpose().matmul(&x);
        for idx in 0..w.data.len() {
            let gi = g.data[idx] / bs as f32;
            m.data[idx] = b1 * m.data[idx] + (1.0 - b1) * gi;
            v.data[idx] = b2 * v.data[idx] + (1.0 - b2) * gi * gi;
            let mh = m.data[idx] / (1.0 - b1.powi(t as i32));
            let vh = v.data[idx] / (1.0 - b2.powi(t as i32));
            w.data[idx] -= lr * mh / (vh.sqrt() + eps);
        }
    }
    (w, s_x)
}
