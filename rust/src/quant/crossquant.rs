//! CrossQuant-style baseline (Liu et al., 2024) — Appendix A.13 comparison.
//!
//! CrossQuant calibrates an *input-axis* scale for the weight matrix (a
//! "smaller quantization kernel") and runs in a W4A8 setting. We implement
//! the published core idea as: column scales `c_j = μ_x,j^α` with a small
//! calibrated α-search restricted around the CrossQuant operating point
//! (α ∈ {0.25, 0.5, 0.75}), 2-norm objective, grouped RTN on the scaled
//! matrix — i.e. AWQ's machinery with CrossQuant's kernel-size choice
//! (group 128, per Table 16's W4A8G128 setting). Documented as a faithful
//! *class* stand-in rather than a line-by-line port (the reference code is
//! not public in this environment); see DESIGN.md §3.

use super::{awq, Calibration, QuantConfig, QuantizedLinear};
use crate::tensor::Matrix;

/// CrossQuant quantization entry point.
pub fn quantize(w: &Matrix, cfg: &QuantConfig, calib: &Calibration) -> QuantizedLinear {
    // CrossQuant's setting: group size 128 regardless of the global default,
    // and a restricted α set.
    let mut c = cfg.clone();
    c.group_size = 128;
    c.awq_grid = 4; // α ∈ {0, .25, .5, .75, 1} — the operating range
    awq::quantize(w, &c, calib)
}

/// Fake-quantize activations to `bits` with per-row (token) absmax scaling —
/// the A8 half of the W4A8 evaluation setting.
pub fn quantize_activations(x: &Matrix, bits: u32) -> Matrix {
    let maxq = ((1i64 << (bits - 1)) - 1) as f32;
    let mut out = x.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            continue;
        }
        let s = amax / maxq;
        for v in row.iter_mut() {
            *v = (*v / s).round().clamp(-maxq - 1.0, maxq) * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{Method, QuantConfig};
    use crate::tensor::Rng;

    #[test]
    fn crossquant_uses_group_128() {
        let w = llm_like(16, 256, 111);
        let mut rng = Rng::new(112);
        let x = Matrix::from_fn(16, 256, |_, _| rng.normal_f32(0.0, 1.0));
        let calib = Calibration::from_activations(x);
        let q = quantize(&w, &QuantConfig::new(Method::CrossQuant, 4), &calib);
        assert_eq!(q.group_size, 128);
        assert_eq!(q.n_groups(), 2);
    }

    #[test]
    fn activation_quant_8bit_nearly_lossless() {
        let mut rng = Rng::new(113);
        let x = Matrix::from_fn(8, 64, |_, _| rng.normal_f32(0.0, 2.0));
        let xq = quantize_activations(&x, 8);
        let rel = xq.dist(&x) / x.dist(&Matrix::zeros(8, 64));
        assert!(rel < 0.01, "8-bit act quant rel err {rel}");
    }

    #[test]
    fn activation_quant_4bit_visibly_lossy() {
        let mut rng = Rng::new(114);
        let x = Matrix::from_fn(8, 64, |_, _| rng.normal_f32(0.0, 2.0));
        let e8 = quantize_activations(&x, 8).dist(&x);
        let e4 = quantize_activations(&x, 4).dist(&x);
        assert!(e4 > e8 * 4.0);
    }

    #[test]
    fn zero_row_unchanged() {
        let x = Matrix::zeros(2, 8);
        let xq = quantize_activations(&x, 8);
        assert_eq!(xq, x);
    }
}
