//! Hadamard weight-space rotation (+RTN) — the standard uncalibrated
//! transformation baseline (Tseng et al. 2024; used by QuaRot/HIGGS).
//!
//! The fast Walsh–Hadamard transform with orthonormal scaling `H/√d`
//! "gaussianizes" weight distributions, easing quantization. We rotate the
//! *input* dimension: store `W' = W·H`; inference computes
//! `y = (x·H)·W'ᵀ`, and [`super::QuantizedLinear::effective_weight`] undoes
//! the rotation for evaluation. Dimensions must be powers of two — all model
//! dims in this repo are chosen accordingly.

use super::{rtn, QuantConfig, QuantizedLinear};
use crate::fmt::grids::Grid;
use crate::tensor::Matrix;

/// In-place orthonormal FWHT of a single vector (length must be 2^k).
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= scale;
    }
}

/// Rotate every row of `w` by H (i.e. `W ← W·H`, rotating the input space).
pub fn rotate_cols(w: &mut Matrix) {
    for i in 0..w.rows {
        fwht(w.row_mut(i));
    }
}

/// Rotate every column of `w` by H (i.e. `W ← H·W`, rotating output space).
pub fn rotate_rows(w: &mut Matrix) {
    let mut col = vec![0.0f32; w.rows];
    for j in 0..w.cols {
        for i in 0..w.rows {
            col[i] = w.at(i, j);
        }
        fwht(&mut col);
        for i in 0..w.rows {
            *w.at_mut(i, j) = col[i];
        }
    }
}

/// Hadamard + RTN baseline: rotate the input space, then grouped RTN.
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    assert!(
        w.cols.is_power_of_two(),
        "hadamard baseline needs power-of-two input dim, got {}",
        w.cols
    );
    let mut rotated = w.clone();
    rotate_cols(&mut rotated);
    let mut q = rtn::quantize(&rotated, cfg);
    q.hadamard = true;
    q
}

/// HIGGS-like baseline: Hadamard rotation + NF (normal-float) grid. HIGGS
/// matches non-uniform levels to the post-rotation Gaussian-like
/// distribution; with our grid abstraction that is exactly Hadamard + NF_b.
pub fn quantize_higgs(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    let mut c = cfg.clone();
    c.grid = Grid::nf(cfg.bits);
    quantize(w, &c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{Method, QuantConfig};
    use crate::tensor::{stats, Rng};

    #[test]
    fn fwht_is_orthonormal_involution() {
        let mut rng = Rng::new(71);
        let orig: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut x = orig.clone();
        fwht(&mut x);
        // Norm preserved.
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
        // H² = I for the orthonormal normalization.
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fwht_known_values() {
        let mut x = vec![1.0, 1.0, 1.0, 1.0];
        fwht(&mut x);
        assert_eq!(x, vec![2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn effective_weight_recovers_original_space() {
        let w = llm_like(16, 64, 72);
        let cfg = QuantConfig::new(Method::HadamardRtn, 8); // 8-bit ≈ lossless
        let q = quantize(&w, &cfg);
        assert!(q.hadamard);
        let eff = q.effective_weight();
        let rel = eff.dist(&w) / w.dist(&Matrix::zeros(16, 64));
        assert!(rel < 0.02, "8-bit hadamard round trip rel err {rel}");
    }

    #[test]
    fn rotation_reduces_kurtosis_of_heavy_tailed_weights() {
        let w = llm_like(64, 128, 73);
        let k0 = stats::mean_row_kurtosis(&w);
        let mut r = w.clone();
        rotate_cols(&mut r);
        let k1 = stats::mean_row_kurtosis(&r);
        assert!(k1 < k0, "kurtosis {k0} -> {k1}");
    }

    #[test]
    fn hadamard_improves_matrix_mse_over_rtn_on_outliers() {
        // Fig. 3a: Hadamard gives better *matrix* reconstruction.
        let w = llm_like(64, 128, 74);
        let e_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 3))
            .dequantize()
            .mse(&w);
        let q = quantize(&w, &QuantConfig::new(Method::HadamardRtn, 3));
        let e_had = q.effective_weight().mse(&w);
        assert!(e_had < e_rtn, "hadamard {e_had:.3e} vs rtn {e_rtn:.3e}");
    }

    #[test]
    fn higgs_uses_nf_grid() {
        let w = llm_like(16, 64, 75);
        let q = quantize_higgs(&w, &QuantConfig::new(Method::Higgs, 4));
        assert!(matches!(q.grid, Grid::Table { .. }));
        assert!(q.hadamard);
    }

    #[test]
    fn rotate_rows_then_cols_composes() {
        let mut rng = Rng::new(76);
        let w = Matrix::randn(32, 64, 1.0, &mut rng);
        let mut r = w.clone();
        rotate_rows(&mut r);
        rotate_cols(&mut r);
        rotate_cols(&mut r);
        rotate_rows(&mut r);
        assert!(r.dist(&w) < 1e-3);
    }
}
