//! Codebook (vector) quantization stand-in for the QuIP# / QTIP comparator
//! class (Appendix A.14).
//!
//! Pipeline: Hadamard incoherence processing on **both** sides
//! (`W'' = H_N·W·H_M`, as in QuIP#), per-(row, group) max-abs normalization,
//! then 2-D vector quantization of adjacent weight pairs against a 256-entry
//! codebook — 8 bits per pair = 4 bits/weight, the same budget as QuIP#'s
//! E8P. The codebook is k-means-trained on the matrix's own normalized pairs
//! (seeded, deterministic), standing in for the fixed E8 lattice: same
//! representational class (paired VQ after incoherence), simpler
//! construction. Documented in DESIGN.md §3 as a class stand-in.

use super::{hadamard, QuantConfig, QuantizedLinear};
use crate::tensor::{Matrix, Rng};

const CODEBOOK_SIZE: usize = 256;

/// Train a 2-D k-means codebook on (already normalized) pairs.
fn train_codebook(pairs: &[(f32, f32)], seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    // Init: sample distinct-ish pairs.
    let mut centers: Vec<(f32, f32)> =
        (0..CODEBOOK_SIZE).map(|_| pairs[rng.below(pairs.len())]).collect();
    let iters = 8;
    let mut assign = vec![0usize; pairs.len()];
    for _ in 0..iters {
        // Assignment.
        for (pi, &(a, b)) in pairs.iter().enumerate() {
            let mut best = (f32::INFINITY, 0usize);
            for (ci, &(ca, cb)) in centers.iter().enumerate() {
                let d = (a - ca) * (a - ca) + (b - cb) * (b - cb);
                if d < best.0 {
                    best = (d, ci);
                }
            }
            assign[pi] = best.1;
        }
        // Update.
        let mut sums = vec![(0.0f64, 0.0f64, 0usize); CODEBOOK_SIZE];
        for (pi, &(a, b)) in pairs.iter().enumerate() {
            let s = &mut sums[assign[pi]];
            s.0 += a as f64;
            s.1 += b as f64;
            s.2 += 1;
        }
        for (ci, s) in sums.iter().enumerate() {
            if s.2 > 0 {
                centers[ci] = ((s.0 / s.2 as f64) as f32, (s.1 / s.2 as f64) as f32);
            } else {
                centers[ci] = pairs[rng.below(pairs.len())]; // re-seed empty cell
            }
        }
    }
    centers.iter().flat_map(|&(a, b)| [a, b]).collect()
}

/// Codebook quantization entry point (4 bits/weight).
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    assert!(w.cols.is_power_of_two() && w.rows.is_power_of_two(),
        "codebook method requires power-of-two dims (incoherence rotation)");
    assert_eq!(w.cols % 2, 0);
    let mut r = w.clone();
    hadamard::rotate_cols(&mut r);
    hadamard::rotate_rows(&mut r);

    // Per-(row, group) max-abs scales; normalized values land in [-1, 1].
    let g = cfg.group_size;
    let n_groups = r.cols.div_ceil(g);
    let mut scales = Matrix::zeros(r.rows, n_groups);
    let mut norm = r.clone();
    for i in 0..r.rows {
        for gi in 0..n_groups {
            let j0 = gi * g;
            let j1 = (j0 + g).min(r.cols);
            let amax = r.row(i)[j0..j1].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = if amax > 0.0 { amax } else { 1.0 };
            *scales.at_mut(i, gi) = s;
            for v in &mut norm.row_mut(i)[j0..j1] {
                *v /= s;
            }
        }
    }

    // Collect pairs and train the codebook.
    let mut pairs = Vec::with_capacity(norm.numel() / 2);
    for i in 0..norm.rows {
        for p in norm.row(i).chunks_exact(2) {
            pairs.push((p[0], p[1]));
        }
    }
    let cb = train_codebook(&pairs, 0xC0DE_B00C);

    // Encode.
    let mut codes = Vec::with_capacity(pairs.len());
    for &(a, b) in &pairs {
        let mut best = (f32::INFINITY, 0u8);
        for ci in 0..CODEBOOK_SIZE {
            let (ca, cbv) = (cb[ci * 2], cb[ci * 2 + 1]);
            let d = (a - ca) * (a - ca) + (b - cbv) * (b - cbv);
            if d < best.0 {
                best = (d, ci as u8);
            }
        }
        codes.push(best.1);
    }

    QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: g,
        grid: cfg.grid.clone(), // unused by the pair decoder; kept for accounting
        codes,
        scales,
        shifts: None,
        col_scale: None,
        hadamard: true,
        hadamard_out: true,
        pair_codebook: Some(cb),
        aux: cfg.aux,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{rtn, Method, QuantConfig};

    #[test]
    fn codebook_round_trip_error_competitive_with_rtn() {
        let w = llm_like(64, 128, 121);
        let q = quantize(&w, &QuantConfig::new(Method::Codebook, 4));
        let e_cb = q.effective_weight().mse(&w);
        let e_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 4)).dequantize().mse(&w);
        // The VQ stand-in should be in the same class (within 2× of RTN).
        assert!(e_cb < e_rtn * 2.0, "codebook {e_cb:.3e} vs rtn {e_rtn:.3e}");
    }

    #[test]
    fn four_bits_per_weight_budget() {
        let w = llm_like(32, 64, 122);
        let q = quantize(&w, &QuantConfig::new(Method::Codebook, 4));
        assert_eq!(q.codes.len(), 32 * 64 / 2); // one byte per pair
        let bpw = q.bits_per_weight();
        assert!(bpw > 4.0 && bpw < 4.6, "bpw {bpw}");
    }

    #[test]
    fn kmeans_reduces_distortion_vs_random_codebook() {
        let w = llm_like(32, 64, 123);
        let q = quantize(&w, &QuantConfig::new(Method::Codebook, 4));
        // Distortion with trained codebook:
        let trained = q.dequantize().mse(&{
            let mut r = w.clone();
            hadamard::rotate_cols(&mut r);
            hadamard::rotate_rows(&mut r);
            r
        });
        assert!(trained.is_finite() && trained > 0.0);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_power_of_two() {
        let w = Matrix::zeros(24, 100);
        let _ = quantize(&w, &QuantConfig::new(Method::Codebook, 4));
    }
}
