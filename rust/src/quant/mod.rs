//! The quantizer zoo: every method the paper proposes or compares against.
//!
//! All methods share one output representation, [`QuantizedLinear`], whose
//! dequantization follows the paper's dual-scale parameterization (Eq. 3):
//!
//! ```text
//! W_approx = s ⊙ (Q + z) ⊙ t
//! ```
//!
//! with `s`, `z` per (row, input-group) and `t` per column. Single-scale
//! methods (RTN, HQQ, GPTQ, …) simply have `t = None`; grid (non-uniform)
//! methods have `z = None` and decode `Q` through a level table.
//!
//! Methods:
//! * [`rtn`] — round-to-nearest, asymmetric or symmetric, any grid.
//! * [`sinq`] — **the paper's contribution**: Algorithm 1 (dampened log-space
//!   Sinkhorn normalization) followed by any base quantizer.
//! * [`hqq`] — half-quadratic quantization (Badri & Shaji 2023).
//! * [`hadamard`] — fast Walsh–Hadamard weight-space rotation + RTN.
//! * [`awq`] — activation-aware calibration (Lin et al. 2024), Eq. 6.
//! * [`asinq`] — A-SINQ: SINQ normalization + AWQ calibration (1-norm).
//! * [`gptq`] — Hessian-based error compensation (Frantar et al. 2022).
//! * [`crossquant`] — input-axis scale calibration (Liu et al. 2024).
//! * [`codebook`] — QuIP#-class stand-in (Hadamard incoherence + 2-D
//!   k-means codebook).
//! * [`fold`] — no-overhead SINQ: absorb `t` into producer layers (§2.3.1).
//! * [`metrics`] — imbalance / kurtosis / reconstruction-error diagnostics.

pub mod awq;
pub mod codebook;
pub mod crossquant;
pub mod fold;
pub mod gptq;
pub mod hadamard;
pub mod hqq;
pub mod metrics;
pub mod rtn;
pub mod sinq;

#[cfg(test)]
pub(crate) mod testutil;

use crate::fmt::grids::Grid;
use crate::fmt::pack;
use crate::tensor::Matrix;
use crate::util::half::round_f16;

/// Which quantization method to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Rtn,
    HadamardRtn,
    Hqq,
    Sinq,
    SinqNoShift,
    Awq,
    ASinq,
    Gptq,
    HadamardGptq,
    CrossQuant,
    Codebook,
    /// BnB-style direct FP4/NF4 (grid chosen in the config).
    BnB,
    /// HIGGS-like: Hadamard + NF grid.
    Higgs,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "rtn",
            Method::HadamardRtn => "hadamard+rtn",
            Method::Hqq => "hqq",
            Method::Sinq => "sinq",
            Method::SinqNoShift => "sinq-noshift",
            Method::Awq => "awq",
            Method::ASinq => "a-sinq",
            Method::Gptq => "gptq",
            Method::HadamardGptq => "hadamard+gptq",
            Method::CrossQuant => "crossquant",
            Method::Codebook => "codebook",
            Method::BnB => "bnb",
            Method::Higgs => "higgs",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "rtn" => Method::Rtn,
            "hadamard" | "hadamard+rtn" => Method::HadamardRtn,
            "hqq" => Method::Hqq,
            "sinq" => Method::Sinq,
            "sinq-noshift" => Method::SinqNoShift,
            "awq" => Method::Awq,
            "a-sinq" | "asinq" => Method::ASinq,
            "gptq" => Method::Gptq,
            "hadamard+gptq" => Method::HadamardGptq,
            "crossquant" => Method::CrossQuant,
            "codebook" => Method::Codebook,
            "bnb" | "bnb-nf4" => Method::BnB,
            "higgs" => Method::Higgs,
            _ => return None,
        })
    }

    /// Does the method need calibration activations?
    pub fn needs_calibration(&self) -> bool {
        matches!(
            self,
            Method::Awq | Method::ASinq | Method::Gptq | Method::HadamardGptq | Method::CrossQuant
        )
    }
}

/// Precision in which auxiliary parameters (scales/shifts) are stored —
/// the Fig. 5a ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuxPrecision {
    F32,
    F16,
    /// 8-bit with one f16 meta-scale per 128 values (HQQ-style).
    I8,
}

impl AuxPrecision {
    pub fn bits(&self) -> f64 {
        match self {
            AuxPrecision::F32 => 32.0,
            AuxPrecision::F16 => 16.0,
            AuxPrecision::I8 => 8.0 + 16.0 / 128.0,
        }
    }
}

/// Full quantization configuration.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub method: Method,
    pub bits: u32,
    /// Group size along the input dimension (paper default 64).
    pub group_size: usize,
    /// Level grid; `Uniform` unless running NF4/FP4 variants.
    pub grid: Grid,
    /// Store a shift `z` (Fig. 5b ablation; dual-scale + shift is the paper
    /// default, §2.1.2).
    pub shift: bool,
    pub aux: AuxPrecision,
    /// Sinkhorn iterations for SINQ (Algorithm 1's `K`).
    pub sinq_iters: usize,
    /// Algorithm 1 step clamp `[s_min, s_max]`.
    pub sinq_clamp: (f32, f32),
    /// HQQ half-quadratic iterations / p-norm.
    pub hqq_iters: usize,
    pub hqq_p: f32,
    /// AWQ α grid resolution (α ∈ {0, 1/n, …, 1}).
    pub awq_grid: usize,
    /// GPTQ Hessian damping fraction.
    pub gptq_damp: f32,
}

impl QuantConfig {
    pub fn new(method: Method, bits: u32) -> QuantConfig {
        QuantConfig {
            method,
            bits,
            group_size: 64,
            grid: Grid::uniform(bits),
            shift: true,
            aux: AuxPrecision::F16,
            sinq_iters: 24,
            sinq_clamp: (0.5, 2.0),
            hqq_iters: 20,
            hqq_p: 0.7,
            awq_grid: 20,
            gptq_damp: 0.01,
        }
    }

    pub fn with_grid(mut self, grid: Grid) -> QuantConfig {
        self.grid = grid;
        self
    }

    pub fn with_group(mut self, g: usize) -> QuantConfig {
        self.group_size = g;
        self
    }

    pub fn with_aux(mut self, aux: AuxPrecision) -> QuantConfig {
        self.aux = aux;
        self
    }

    pub fn with_shift(mut self, shift: bool) -> QuantConfig {
        self.shift = shift;
        self
    }
}

/// Calibration data for activation-aware methods: a sample of layer inputs
/// `X` (n_samples × in_features) and the mean absolute input `μ_x`.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub x: Matrix,
    pub mu_x: Vec<f32>,
}

impl Calibration {
    pub fn from_activations(x: Matrix) -> Calibration {
        let mut mu = vec![0.0f32; x.cols];
        for i in 0..x.rows {
            for (j, &v) in x.row(i).iter().enumerate() {
                mu[j] += v.abs();
            }
        }
        let n = x.rows.max(1) as f32;
        for m in &mut mu {
            *m /= n;
            // Guard: dead inputs would produce zero or infinite scales.
            if *m < 1e-8 {
                *m = 1e-8;
            }
        }
        Calibration { x, mu_x: mu }
    }
}

/// The unified quantized-layer representation (Eq. 3 dequantization).
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    pub grid: Grid,
    /// Unsigned codes, row-major, `rows*cols` entries.
    pub codes: Vec<u8>,
    /// Per (row, group) scale — includes any merged Sinkhorn row scale
    /// (`s_q ⊙ s` from Algorithm 1 line 19).
    pub scales: Matrix,
    /// Per (row, group) shift `z` (uniform asymmetric quantization only).
    pub shifts: Option<Matrix>,
    /// Second-axis (column) scale `t` — present for dual-scale methods.
    pub col_scale: Option<Vec<f32>>,
    /// Weights stored in the Hadamard-rotated input space (`W' = W·H`);
    /// `effective_weight` un-rotates.
    pub hadamard: bool,
    /// Output-side Hadamard rotation (codebook methods rotate both sides).
    pub hadamard_out: bool,
    /// Codebook for 2-D vector quantization (codebook method only):
    /// flattened (k, 2) entries; `codes` then hold per-pair indices.
    pub pair_codebook: Option<Vec<f32>>,
    /// Aux precision used (memory accounting).
    pub aux: AuxPrecision,
}

impl QuantizedLinear {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Dequantize to the stored-space matrix `s ⊙ (Q + z) ⊙ t` (no Hadamard
    /// unrotation — see [`QuantizedLinear::effective_weight`]).
    pub fn dequantize(&self) -> Matrix {
        if let Some(cb) = &self.pair_codebook {
            return self.dequantize_pairs(cb);
        }
        let g = self.group_size;
        let mut w = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                let gi = j / g;
                let s = self.scales.at(i, gi);
                let q = self.grid.decode(self.codes[i * self.cols + j]);
                let z = self.shifts.as_ref().map(|z| z.at(i, gi)).unwrap_or(0.0);
                w.data[i * self.cols + j] = s * (q + z);
            }
        }
        if let Some(t) = &self.col_scale {
            w.scale_cols(t);
        }
        w
    }

    fn dequantize_pairs(&self, cb: &[f32]) -> Matrix {
        let mut w = Matrix::zeros(self.rows, self.cols);
        let g = self.group_size;
        for i in 0..self.rows {
            for p in 0..self.cols / 2 {
                let idx = self.codes[i * self.cols / 2 + p] as usize;
                let (a, b) = (cb[idx * 2], cb[idx * 2 + 1]);
                let j = p * 2;
                let s = self.scales.at(i, j / g);
                w.data[i * self.cols + j] = s * a;
                w.data[i * self.cols + j + 1] = s * b;
            }
        }
        w
    }

    /// The effective weight seen by the unquantized network: dequantize and
    /// undo any Hadamard rotations so `y = x · Wᵀ_eff` is directly comparable
    /// with the original layer.
    pub fn effective_weight(&self) -> Matrix {
        let mut w = self.dequantize();
        if self.hadamard {
            // Stored W' = W·H with orthonormal H ⇒ W = W'·Hᵀ = W'·H (H sym).
            hadamard::rotate_cols(&mut w);
        }
        if self.hadamard_out {
            hadamard::rotate_rows(&mut w);
        }
        w
    }

    /// Packed weight bytes (codes bit-packed at the grid width).
    pub fn packed_weight_bytes(&self) -> usize {
        if self.pair_codebook.is_some() {
            // one 8-bit index per 2 weights
            return self.rows * self.cols / 2;
        }
        pack::packed_len(self.rows * self.cols, self.grid.bits())
    }

    /// Auxiliary parameter bytes: scales + shifts at `aux` precision, plus
    /// the `t` vector (f16), plus any codebook.
    pub fn aux_bytes(&self) -> usize {
        let per = self.aux.bits() / 8.0;
        let mut n = (self.scales.numel() as f64 * per) as usize;
        if let Some(z) = &self.shifts {
            n += (z.numel() as f64 * per) as usize;
        }
        if let Some(t) = &self.col_scale {
            n += t.len() * 2; // f16
        }
        // The pair codebook is shared across every layer of a model; it is
        // accounted once at model level (see `model::memory`), not per layer.
        n
    }

    pub fn total_bytes(&self) -> usize {
        self.packed_weight_bytes() + self.aux_bytes()
    }

    /// Bits per weight including auxiliaries (paper's "Mem." accounting).
    pub fn bits_per_weight(&self) -> f64 {
        self.total_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// Round an aux parameter matrix to the configured precision in place.
/// I8 uses HQQ-style 8-bit blocks of 128 with an f16 meta-scale.
pub fn apply_aux_precision(m: &mut Matrix, aux: AuxPrecision) {
    match aux {
        AuxPrecision::F32 => {}
        AuxPrecision::F16 => {
            for v in &mut m.data {
                *v = round_f16(*v);
            }
        }
        AuxPrecision::I8 => {
            for block in m.data.chunks_mut(128) {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for &v in block.iter() {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
                let scale = round_f16(scale).max(1e-8);
                let zero = round_f16(lo);
                for v in block {
                    let q = ((*v - zero) / scale).round().clamp(0.0, 255.0);
                    *v = zero + q * scale;
                }
            }
        }
    }
}

/// Top-level dispatch: quantize one weight matrix (rows = out features,
/// cols = in features) with the configured method.
pub fn quantize_matrix(
    w: &Matrix,
    cfg: &QuantConfig,
    calib: Option<&Calibration>,
) -> anyhow::Result<QuantizedLinear> {
    quantize_matrix_traced(w, cfg, calib).map(|(q, _)| q)
}

/// [`quantize_matrix`], also returning the Sinkhorn normalization outcome
/// for methods that normalize (`sinq`/`sinq-noshift`); `None` otherwise.
/// Feeds the build-time quantization-quality report.
pub fn quantize_matrix_traced(
    w: &Matrix,
    cfg: &QuantConfig,
    calib: Option<&Calibration>,
) -> anyhow::Result<(QuantizedLinear, Option<sinq::SinkhornScales>)> {
    let need = cfg.method.needs_calibration();
    anyhow::ensure!(
        !need || calib.is_some(),
        "method {} requires calibration data",
        cfg.method.name()
    );
    Ok(match cfg.method {
        Method::Rtn => (rtn::quantize(w, cfg), None),
        Method::BnB => (rtn::quantize(w, cfg), None), // grid carries FP4/NF4
        Method::HadamardRtn => (hadamard::quantize(w, cfg), None),
        Method::Higgs => (hadamard::quantize_higgs(w, cfg), None),
        Method::Hqq => (hqq::quantize(w, cfg), None),
        Method::Sinq | Method::SinqNoShift => {
            let (q, scales) = sinq::quantize_with_stats(w, cfg);
            (q, Some(scales))
        }
        Method::Awq => (awq::quantize(w, cfg, calib.unwrap()), None),
        Method::ASinq => (awq::quantize_asinq(w, cfg, calib.unwrap()), None),
        Method::Gptq => (gptq::quantize(w, cfg, calib.unwrap(), false), None),
        Method::HadamardGptq => (gptq::quantize(w, cfg, calib.unwrap(), true), None),
        Method::CrossQuant => (crossquant::quantize(w, cfg, calib.unwrap()), None),
        Method::Codebook => (codebook::quantize(w, cfg), None),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn method_parse_round_trip() {
        for m in [
            Method::Rtn,
            Method::HadamardRtn,
            Method::Hqq,
            Method::Sinq,
            Method::Awq,
            Method::ASinq,
            Method::Gptq,
            Method::CrossQuant,
            Method::Codebook,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m), "{}", m.name());
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn calibration_mu_is_mean_abs() {
        let x = Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.0, 3.0, -4.0, 0.0]);
        let c = Calibration::from_activations(x);
        assert!((c.mu_x[0] - 2.0).abs() < 1e-6);
        assert!((c.mu_x[1] - 3.0).abs() < 1e-6);
        assert!(c.mu_x[2] > 0.0); // guarded against zero
    }

    #[test]
    fn calibrated_methods_require_calibration() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(8, 64, 0.02, &mut rng);
        let cfg = QuantConfig::new(Method::Awq, 4);
        assert!(quantize_matrix(&w, &cfg, None).is_err());
    }

    #[test]
    fn aux_precision_i8_bounded_error() {
        let mut rng = Rng::new(2);
        let mut m = Matrix::randn(4, 100, 1.0, &mut rng);
        let orig = m.clone();
        apply_aux_precision(&mut m, AuxPrecision::I8);
        for (a, b) in m.data.iter().zip(&orig.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn bits_per_weight_accounting() {
        // 4-bit, g=64, f16 aux, with shift and t:
        // 4 + (16+16)/64 + 16/rows ≈ 4.5 + small.
        let mut rng = Rng::new(3);
        let w = Matrix::randn(64, 128, 0.02, &mut rng);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let q = quantize_matrix(&w, &cfg, None).unwrap();
        let bpw = q.bits_per_weight();
        assert!(bpw > 4.4 && bpw < 5.0, "bits/weight {bpw}");
    }
}
