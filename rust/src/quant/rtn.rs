//! Round-to-nearest quantization — the trivial-but-strong baseline and the
//! rounding primitive every other method builds on.
//!
//! Uniform grids use asymmetric min/max quantization per (row, group):
//! `scale = (max−min)/(2^b−1)`, `z = min/scale`, codes
//! `q = round(w/scale − z) ∈ [0, 2^b−1]`, dequantizing to `scale·(q+z)`.
//! Without shift (Fig. 5b ablation) a symmetric max-abs scale is used with an
//! implicit mid-grid shift. Table grids (NF4/FP4) use max-abs normalization
//! and nearest-level lookup — exactly BnB semantics.

use super::{apply_aux_precision, QuantConfig, QuantizedLinear};
use crate::fmt::grids::Grid;
use crate::tensor::Matrix;

/// Result of quantizing one group-row slice.
pub struct GroupQuant {
    pub scale: f32,
    pub shift: f32,
    pub codes: Vec<u8>,
}

/// Quantize one contiguous slice against a grid.
pub fn quantize_group(w: &[f32], grid: &Grid, shift: bool) -> GroupQuant {
    match grid {
        Grid::Uniform { bits } => {
            let maxq = ((1u32 << bits) - 1) as f32;
            if shift {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for &v in w {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                // Always include 0 in the representable range (keeps exact
                // zeros exact; matches common RTN implementations).
                lo = lo.min(0.0);
                hi = hi.max(0.0);
                let scale = if hi > lo { (hi - lo) / maxq } else { 1.0 };
                let z = lo / scale;
                let codes =
                    w.iter().map(|&v| (v / scale - z).round().clamp(0.0, maxq) as u8).collect();
                GroupQuant { scale, shift: z, codes }
            } else {
                let amax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let half = ((1u32 << (bits - 1)) - 1) as f32; // e.g. 7 for 4-bit
                let scale = if amax > 0.0 { amax / half } else { 1.0 };
                let z = -(1i64 << (bits - 1)) as f32; // implicit center, e.g. −8
                let codes = w
                    .iter()
                    .map(|&v| ((v / scale) - z).round().clamp(0.0, maxq) as u8)
                    .collect();
                GroupQuant { scale, shift: z, codes }
            }
        }
        Grid::Table { .. } => {
            let amax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = if amax > 0.0 { amax } else { 1.0 };
            let codes = w.iter().map(|&v| grid.nearest(v / scale)).collect();
            GroupQuant { scale, shift: 0.0, codes }
        }
    }
}

/// Quantize a full matrix group-wise along the input dimension. This is the
/// `RoundToNearest(Ŵ, b)` of Algorithm 1 line 18 and the RTN baseline itself.
///
/// Returns codes plus per-(row, group) scale/shift matrices.
pub fn quantize_grouped(
    w: &Matrix,
    grid: &Grid,
    group_size: usize,
    shift: bool,
) -> (Vec<u8>, Matrix, Option<Matrix>) {
    let n_groups = w.cols.div_ceil(group_size);
    let mut codes = vec![0u8; w.rows * w.cols];
    let mut scales = Matrix::zeros(w.rows, n_groups);
    let use_shift = shift && grid.is_uniform();
    let mut shifts = if use_shift { Some(Matrix::zeros(w.rows, n_groups)) } else { None };
    // Symmetric uniform also records its constant implicit shift so the
    // shared dequantizer needs no special case.
    let mut const_shift = if !shift && grid.is_uniform() {
        Some(Matrix::zeros(w.rows, n_groups))
    } else {
        None
    };

    for i in 0..w.rows {
        let row = w.row(i);
        for g in 0..n_groups {
            let j0 = g * group_size;
            let j1 = (j0 + group_size).min(w.cols);
            let gq = quantize_group(&row[j0..j1], grid, shift);
            *scales.at_mut(i, g) = gq.scale;
            if let Some(z) = shifts.as_mut() {
                *z.at_mut(i, g) = gq.shift;
            }
            if let Some(z) = const_shift.as_mut() {
                *z.at_mut(i, g) = gq.shift;
            }
            codes[i * w.cols + j0..i * w.cols + j1].copy_from_slice(&gq.codes);
        }
    }
    (codes, scales, shifts.or(const_shift))
}

/// RTN entry point for the dispatcher: quantize with the configured grid and
/// round auxiliaries to the configured precision.
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    let (codes, mut scales, mut shifts) =
        quantize_grouped(w, &cfg.grid, cfg.group_size, cfg.shift);
    apply_aux_precision(&mut scales, cfg.aux);
    if let Some(z) = shifts.as_mut() {
        apply_aux_precision(z, cfg.aux);
    }
    QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: cfg.group_size,
        grid: cfg.grid.clone(),
        codes,
        scales,
        shifts,
        col_scale: None,
        hadamard: false,
        hadamard_out: false,
        pair_codebook: None,
        aux: cfg.aux,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;
    use crate::tensor::{Matrix, Rng};

    fn rel_err(w: &Matrix, q: &QuantizedLinear) -> f64 {
        let deq = q.dequantize();
        (deq.mse(w) / w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            * w.numel() as f64)
            .sqrt()
    }

    #[test]
    fn rtn_4bit_small_error() {
        let mut rng = Rng::new(51);
        let w = Matrix::randn(32, 128, 0.02, &mut rng);
        let cfg = QuantConfig::new(Method::Rtn, 4);
        let q = quantize(&w, &cfg);
        assert!(rel_err(&w, &q) < 0.12, "rel err {}", rel_err(&w, &q));
    }

    #[test]
    fn rtn_3bit_worse_than_4bit() {
        let mut rng = Rng::new(52);
        let w = Matrix::randn(32, 128, 0.02, &mut rng);
        let e4 = rel_err(&w, &quantize(&w, &QuantConfig::new(Method::Rtn, 4)));
        let e3 = rel_err(&w, &quantize(&w, &QuantConfig::new(Method::Rtn, 3)));
        assert!(e3 > e4 * 1.5, "3-bit {e3} vs 4-bit {e4}");
    }

    #[test]
    fn exact_zero_preserved_with_shift() {
        let w = Matrix::from_vec(1, 8, vec![0.0, 0.5, 1.0, 0.0, -0.25, 0.75, 0.0, 0.125]);
        let cfg = QuantConfig::new(Method::Rtn, 4).with_group(8);
        let q = quantize(&w, &cfg);
        let deq = q.dequantize();
        for j in [0usize, 3, 6] {
            assert!(deq.at(0, j).abs() < 1e-3, "zero at {j} became {}", deq.at(0, j));
        }
    }

    #[test]
    fn symmetric_mode_has_constant_shift() {
        let mut rng = Rng::new(53);
        let w = Matrix::randn(4, 64, 0.02, &mut rng);
        let cfg = QuantConfig::new(Method::Rtn, 4).with_shift(false);
        let q = quantize(&w, &cfg);
        let z = q.shifts.as_ref().unwrap();
        assert!(z.data.iter().all(|&v| v == -8.0));
        assert!(rel_err(&w, &q) < 0.18);
    }

    #[test]
    fn codes_within_grid() {
        let mut rng = Rng::new(54);
        let w = Matrix::randn(8, 96, 1.0, &mut rng);
        for bits in [2u32, 3, 4, 8] {
            let cfg = QuantConfig::new(Method::Rtn, bits);
            let q = quantize(&w, &cfg);
            let maxc = 1u32 << bits;
            assert!(q.codes.iter().all(|&c| (c as u32) < maxc), "bits={bits}");
        }
    }

    #[test]
    fn nf4_beats_uniform_on_gaussian() {
        // Gaussian weights are exactly NF4's design target.
        let mut rng = Rng::new(55);
        let w = Matrix::randn(64, 256, 0.02, &mut rng);
        let eu = rel_err(&w, &quantize(&w, &QuantConfig::new(Method::Rtn, 4).with_shift(false)));
        let en = rel_err(
            &w,
            &quantize(&w, &QuantConfig::new(Method::BnB, 4).with_grid(Grid::nf4())),
        );
        assert!(en < eu, "nf4 {en} vs uniform-sym {eu}");
    }

    #[test]
    fn group_size_controls_aux_count() {
        let mut rng = Rng::new(56);
        let w = Matrix::randn(16, 128, 0.02, &mut rng);
        let q64 = quantize(&w, &QuantConfig::new(Method::Rtn, 4).with_group(64));
        let q32 = quantize(&w, &QuantConfig::new(Method::Rtn, 4).with_group(32));
        assert_eq!(q64.scales.numel() * 2, q32.scales.numel());
        assert!(q32.bits_per_weight() > q64.bits_per_weight());
    }

    #[test]
    fn ragged_final_group() {
        let mut rng = Rng::new(57);
        let w = Matrix::randn(4, 100, 0.02, &mut rng); // 100 = 64 + 36
        let cfg = QuantConfig::new(Method::Rtn, 4);
        let q = quantize(&w, &cfg);
        assert_eq!(q.n_groups(), 2);
        assert!(rel_err(&w, &q) < 0.15);
    }
}
