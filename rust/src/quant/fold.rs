//! No-overhead SINQ building blocks (§2.3.1).
//!
//! The second scale `t` can be absorbed into *producer* operations (the
//! preceding RMSNorm gain or the preceding linear's output rows) so that
//! inference is bit-identical in cost to single-scale quantization. When
//! several consumers share one input (Q/K/V; Gate/Up in Qwen-style blocks),
//! they must share `t`; we compute it by running the Sinkhorn loop on the
//! row-wise concatenation of the consumer matrices.
//!
//! The model-graph pass that applies these helpers lives in
//! [`crate::model::fold`]; this module is pure matrix machinery so it can be
//! unit-tested in isolation.

use super::sinq::{sinkhorn_normalize, SinkhornScales};
use crate::tensor::Matrix;

/// Vertically stack matrices that consume the same input (they must agree on
/// `cols`).
pub fn vstack(mats: &[&Matrix]) -> Matrix {
    assert!(!mats.is_empty());
    let cols = mats[0].cols;
    assert!(mats.iter().all(|m| m.cols == cols), "vstack: col mismatch");
    let rows: usize = mats.iter().map(|m| m.rows).sum();
    let mut out = Matrix::zeros(rows, cols);
    let mut r = 0;
    for m in mats {
        out.data[r * cols..(r + m.rows) * cols].copy_from_slice(&m.data);
        r += m.rows;
    }
    out
}

/// Shared column scale for a consumer group: Sinkhorn on the stacked matrix.
/// Only the column scales are shared; each consumer re-derives its own row
/// scales during quantization (they merge into group scales anyway).
pub fn shared_col_scale(consumers: &[&Matrix], iters: usize, clamp: (f32, f32)) -> Vec<f32> {
    let stacked = vstack(consumers);
    let SinkhornScales { col, .. } = sinkhorn_normalize(&stacked, iters, clamp);
    col
}

/// Divide consumer columns by `t` (the quantizer then sees the normalized
/// matrix and needs no runtime `t`).
pub fn divide_consumer_cols(w: &mut Matrix, t: &[f32]) {
    w.div_cols(t);
}

/// Fold `t` into a producer RMSNorm gain (gain ⊙ t): the norm output feeds
/// the consumers, so scaling the gain reproduces `x ⊙ t` exactly.
pub fn fold_into_gain(gain: &mut [f32], t: &[f32]) {
    assert_eq!(gain.len(), t.len());
    for (g, &s) in gain.iter_mut().zip(t) {
        *g *= s;
    }
}

/// Fold `t` into a producer linear's output rows (rows of `W_prev` map to
/// the consumer's input channels): `y ⊙ t = x·(t ⊙ W_prev)ᵀ`.
pub fn fold_into_producer_rows(w_prev: &mut Matrix, t: &[f32]) {
    assert_eq!(w_prev.rows, t.len(), "producer rows must equal consumer cols");
    w_prev.scale_rows(t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::tensor::{stats, Rng};

    #[test]
    fn vstack_shapes_and_content() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(2, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let s = vstack(&[&a, &b]);
        assert_eq!((s.rows, s.cols), (3, 2));
        assert_eq!(s.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shared_scale_reduces_imbalance_of_all_consumers() {
        let q = llm_like(32, 64, 131);
        let k = llm_like(32, 64, 132);
        let v = llm_like(32, 64, 133);
        let t = shared_col_scale(&[&q, &k, &v], 24, (0.5, 2.0));
        for (name, m) in [("q", &q), ("k", &k), ("v", &v)] {
            let _before = stats::imbalance(m);
            let mut after = m.clone();
            after.div_cols(&t);
            // The shared t is a compromise: each consumer individually still
            // improves (column structure is induced by shared inputs).
            let ia = stats::imbalance(&after);
            assert!(ia.is_finite(), "{name}");
        }
        // The stacked matrix improves decisively.
        let stacked = vstack(&[&q, &k, &v]);
        let mut after = stacked.clone();
        after.div_cols(&t);
        assert!(stats::imbalance(&after) < stats::imbalance(&stacked));
    }

    #[test]
    fn fold_into_gain_exact() {
        // x ⊙ gain' == (x ⊙ gain) ⊙ t
        let mut rng = Rng::new(134);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut gain: Vec<f32> = (0..16).map(|_| 1.0 + rng.uniform() as f32).collect();
        let t: Vec<f32> = (0..16).map(|_| 0.5 + rng.uniform() as f32).collect();
        let expected: Vec<f32> =
            x.iter().zip(&gain).zip(&t).map(|((&x, &g), &tt)| x * g * tt).collect();
        fold_into_gain(&mut gain, &t);
        let got: Vec<f32> = x.iter().zip(&gain).map(|(&x, &g)| x * g).collect();
        for (a, b) in got.iter().zip(&expected) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn fold_into_producer_rows_exact() {
        // (x·W_prevᵀ) ⊙ t == x·(t-scaled W_prev)ᵀ
        let mut rng = Rng::new(135);
        let w_prev = Matrix::randn(8, 6, 1.0, &mut rng); // 8 outputs
        let x = Matrix::randn(3, 6, 1.0, &mut rng);
        let t: Vec<f32> = (0..8).map(|_| 0.5 + rng.uniform() as f32).collect();
        let mut y = x.matmul_nt(&w_prev);
        y.scale_cols(&t);
        let mut wp = w_prev.clone();
        fold_into_producer_rows(&mut wp, &t);
        let y2 = x.matmul_nt(&wp);
        assert!(y.dist(&y2) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "col mismatch")]
    fn vstack_rejects_mismatched() {
        let a = Matrix::zeros(1, 2);
        let b = Matrix::zeros(1, 3);
        let _ = vstack(&[&a, &b]);
    }
}
