//! SINQ — Algorithm 1: dampened log-space Sinkhorn-Knopp normalization.
//!
//! The algorithm iteratively normalizes the row and column standard
//! deviations of the weight matrix toward a common target `τ` (the smallest
//! initial std), tracking the iterate with the lowest *imbalance*
//! `I(Ŵ) = max(σ_row, σ_col)/min(σ_row, σ_col)` (Eq. 5). The resulting
//! column scales `t` correlate with the layer's mean absolute input `μ_x`
//! even though no calibration data is used (§2.2.1) — calibration-free
//! pseudo-activation-awareness — while the simultaneous row normalization
//! avoids the row-kurtosis blow-up naive column scaling causes (Fig. 2c).
//!
//! After normalization any base quantizer applies; per Algorithm 1 line 18 we
//! use grouped RTN, and line 19 merges the Sinkhorn row scale into the RTN
//! group scales (`s_q ⊙ s`) so only `t` (one f16 per column) is extra —
//! `2·N·M/T + M` auxiliaries (§2.1.2).

use super::{apply_aux_precision, rtn, QuantConfig, QuantizedLinear};
use crate::tensor::stats;
use crate::tensor::Matrix;
use crate::util::half::round_f16;

/// Output of the normalization loop.
#[derive(Debug, Clone)]
pub struct SinkhornScales {
    /// Row scales `s = exp(u*)`, length `rows`.
    pub row: Vec<f32>,
    /// Column scales `t = exp(v*)`, length `cols`.
    pub col: Vec<f32>,
    /// Imbalance of the best iterate.
    pub imbalance: f64,
    /// Imbalance of the input matrix (for diagnostics).
    pub initial_imbalance: f64,
    /// Iterations until the best iterate was reached (`k` of Algorithm 1's
    /// best-tracking loop) — the layer's effective convergence speed.
    pub iters: usize,
}

/// Algorithm 1 lines 1–17: find `s`, `t` minimizing the imbalance of
/// `W ⊘ s ⊘ t`. `iters` = K, `clamp` = (s_min, s_max).
pub fn sinkhorn_normalize(w: &Matrix, iters: usize, clamp: (f32, f32)) -> SinkhornScales {
    let (m, n) = (w.rows, w.cols);
    let (s_min, s_max) = (clamp.0 as f64, clamp.1 as f64);

    // Line 1–2: target std τ = min over initial row/col stds.
    let sig_row = stats::row_stds(w);
    let sig_col = stats::col_stds(w);
    let tau = sig_row
        .iter()
        .chain(sig_col.iter())
        .cloned()
        .fold(f64::INFINITY, f64::min)
        .max(1e-12);

    // Line 3–4: log-scales u, v; best-iterate tracking.
    let mut u = vec![0.0f64; m];
    let mut v = vec![0.0f64; n];
    let mut best_u = u.clone();
    let mut best_v = v.clone();
    let initial_imbalance = stats::imbalance(w);
    let mut best_i = f64::INFINITY;
    let mut best_k = 0usize;

    let mut w_hat = w.clone();
    for k in 0..iters {
        // Line 6: Ŵ = (W ⊘ exp(u)) ⊘ exp(v). Rebuilt from the original W so
        // u/v always mean *total* log-scales (matches the algorithm listing).
        w_hat.data.copy_from_slice(&w.data);
        for i in 0..m {
            let ru = (-u[i]).exp() as f32;
            for x in w_hat.row_mut(i) {
                *x *= ru;
            }
        }
        let cv: Vec<f32> = v.iter().map(|&x| (-x).exp() as f32).collect();
        w_hat.scale_cols(&cv);

        // Line 7–10: imbalance bookkeeping.
        let i_curr = stats::imbalance(&w_hat);
        if i_curr < best_i {
            best_i = i_curr;
            best_k = k;
            best_u.copy_from_slice(&u);
            best_v.copy_from_slice(&v);
        }

        // Lines 11–14: dampened updates δ = log(clamp(σ/τ, s_min, s_max)).
        let sc = stats::col_stds(&w_hat);
        let sr = stats::row_stds(&w_hat);
        for (vj, &sig) in v.iter_mut().zip(sc.iter()) {
            *vj += (sig / tau).clamp(s_min, s_max).ln();
        }
        for (ui, &sig) in u.iter_mut().zip(sr.iter()) {
            *ui += (sig / tau).clamp(s_min, s_max).ln();
        }
    }

    // Line 16: recover best linear scales.
    SinkhornScales {
        row: best_u.iter().map(|&x| x.exp() as f32).collect(),
        col: best_v.iter().map(|&x| x.exp() as f32).collect(),
        imbalance: best_i,
        initial_imbalance,
        iters: best_k,
    }
}

/// Full SINQ quantization (Algorithm 1): normalize, RTN the normalized
/// matrix, merge row scales, return the dual-scale layer.
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    quantize_with_stats(w, cfg).0
}

/// [`quantize`], also returning the normalization outcome ([`SinkhornScales`]
/// with iterations-to-convergence and before/after imbalance) for the
/// build-time quantization-quality report.
pub fn quantize_with_stats(w: &Matrix, cfg: &QuantConfig) -> (QuantizedLinear, SinkhornScales) {
    let scales = sinkhorn_normalize(w, cfg.sinq_iters, cfg.sinq_clamp);

    // Line 17: Ŵ = (W ⊘ s) ⊘ t.
    let mut w_hat = w.clone();
    w_hat.div_rows(&scales.row);
    w_hat.div_cols(&scales.col);

    // Line 18: base rounding (uniform RTN by default; NF4 grid for SINQ-NF4).
    let use_shift = cfg.shift && !matches!(cfg.method, super::Method::SinqNoShift);
    let (codes, mut s_q, mut shifts) =
        rtn::quantize_grouped(&w_hat, &cfg.grid, cfg.group_size, use_shift);

    // Line 19: merge s into the group scales (s_q ⊙ s); t stays separate
    // (stored f16, appliable to activations instead — Eq. 7).
    for i in 0..w.rows {
        let s = scales.row[i];
        for g in 0..s_q.cols {
            *s_q.at_mut(i, g) *= s;
        }
    }
    apply_aux_precision(&mut s_q, cfg.aux);
    if let Some(z) = shifts.as_mut() {
        apply_aux_precision(z, cfg.aux);
    }
    let t: Vec<f32> = scales.col.iter().map(|&x| round_f16(x)).collect();

    let q = QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: cfg.group_size,
        grid: cfg.grid.clone(),
        codes,
        scales: s_q,
        shifts,
        col_scale: Some(t),
        hadamard: false,
        hadamard_out: false,
        pair_codebook: None,
        aux: cfg.aux,
    };
    (q, scales)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fmt::grids::Grid;
    use crate::quant::testutil::llm_like;
    use crate::quant::{rtn, Method, QuantConfig};
    use crate::tensor::Rng;

    #[test]
    fn normalization_reduces_imbalance() {
        let w = llm_like(64, 128, 61);
        let s = sinkhorn_normalize(&w, 24, (0.5, 2.0));
        assert!(
            s.imbalance < s.initial_imbalance * 0.5,
            "imbalance {} -> {}",
            s.initial_imbalance,
            s.imbalance
        );
        assert!(s.imbalance >= 1.0);
        assert!(s.iters < 24, "best iterate index {} out of range", s.iters);
    }

    #[test]
    fn normalized_stds_near_uniform() {
        let w = llm_like(48, 96, 62);
        let s = sinkhorn_normalize(&w, 32, (0.5, 2.0));
        let mut w_hat = w.clone();
        w_hat.div_rows(&s.row);
        w_hat.div_cols(&s.col);
        let rs = stats::row_stds(&w_hat);
        let cs = stats::col_stds(&w_hat);
        let hi = rs.iter().chain(cs.iter()).cloned().fold(f64::MIN, f64::max);
        let lo = rs.iter().chain(cs.iter()).cloned().fold(f64::MAX, f64::min);
        assert!(hi / lo < 4.0, "residual imbalance {}", hi / lo);
    }

    #[test]
    fn identity_scales_on_already_balanced_matrix() {
        // An i.i.d. Gaussian matrix is already balanced: scales ≈ 1.
        let mut rng = Rng::new(63);
        let w = Matrix::randn(64, 64, 0.02, &mut rng);
        let s = sinkhorn_normalize(&w, 16, (0.5, 2.0));
        // Scales may drift together (global factor), but relative spread is small.
        let smax = s.row.iter().fold(f32::MIN, |m, &x| m.max(x));
        let smin = s.row.iter().fold(f32::MAX, |m, &x| m.min(x));
        assert!(smax / smin < 1.6, "row scale spread {}", smax / smin);
    }

    #[test]
    fn sinq_beats_rtn_on_llm_like_weights() {
        let w = llm_like(128, 256, 64);
        for bits in [3u32, 4] {
            let q_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, bits));
            let q_sinq = quantize(&w, &QuantConfig::new(Method::Sinq, bits));
            let e_rtn = q_rtn.dequantize().mse(&w);
            let e_sinq = q_sinq.effective_weight().mse(&w);
            assert!(
                e_sinq < e_rtn,
                "bits={bits}: sinq {e_sinq:.3e} not better than rtn {e_rtn:.3e}"
            );
        }
    }

    #[test]
    fn sinq_dual_scale_reconstruction_consistent() {
        // dequantize() must equal s⊙(Q+z)⊙t computed manually.
        let w = llm_like(16, 64, 65);
        let cfg = QuantConfig::new(Method::Sinq, 4).with_group(32);
        let q = quantize(&w, &cfg);
        let deq = q.dequantize();
        let t = q.col_scale.as_ref().unwrap();
        for i in 0..q.rows {
            for j in 0..q.cols {
                let g = j / q.group_size;
                let manual = q.scales.at(i, g)
                    * (q.codes[i * q.cols + j] as f32 + q.shifts.as_ref().unwrap().at(i, g))
                    * t[j];
                assert!((deq.at(i, j) - manual).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sinq_reduces_row_kurtosis_vs_naive_col_scaling() {
        // Fig. 2b + 2c on Adam-stationary weights: (a) the pseudo-activation-
        // awareness relation sigma_col(W) ~ 1/sqrt(s_x) emerges; (b) SINQ's
        // joint row/col normalization does not raise row kurtosis beyond the
        // naive 1/sigma_col column scaling.
        let (w, s_x) = crate::quant::testutil::adam_stationary(32, 64, 1000, 266);
        let cs = stats::col_stds(&w);
        let lx: Vec<f64> = s_x.iter().map(|&s| (1.0 / (s as f64).sqrt()).ln()).collect();
        let ls: Vec<f64> = cs.iter().map(|&c| c.max(1e-12).ln()).collect();
        let r2 = stats::r_squared(&lx, &ls);
        assert!(r2 > 0.5, "Fig 2b relation absent: R^2 = {r2}");

        let mut naive = w.clone();
        naive.div_cols(&cs.iter().map(|&x| x as f32).collect::<Vec<_>>());
        let naive_k = stats::mean_row_kurtosis(&naive);

        let s = sinkhorn_normalize(&w, 24, (0.5, 2.0));
        let mut sq = w.clone();
        sq.div_rows(&s.row);
        sq.div_cols(&s.col);
        let sinq_k = stats::mean_row_kurtosis(&sq);
        assert!(
            sinq_k <= naive_k * 1.1,
            "sinq kurtosis {sinq_k} vs naive {naive_k}"
        );

        // And the derived t correlates with mu_x (= s_x * sqrt(2/pi)).
        let lmu: Vec<f64> = s_x.iter().map(|&x| (x as f64).ln()).collect();
        let lt: Vec<f64> = s.col.iter().map(|&t| (t as f64).max(1e-12).ln()).collect();
        let r2t = stats::r_squared(&lmu, &lt);
        assert!(r2t > 0.5, "t not predictive of mu_x: R^2 = {r2t}");
    }

    #[test]
    fn sinq_nf4_works() {
        let w = llm_like(32, 128, 67);
        let cfg = QuantConfig::new(Method::Sinq, 4).with_grid(Grid::nf4());
        let q = quantize(&w, &cfg);
        assert!(q.shifts.is_none()); // table grids carry no shift
        let e = q.dequantize().mse(&w);
        let e_bnb = rtn::quantize(&w, &QuantConfig::new(Method::BnB, 4).with_grid(Grid::nf4()))
            .dequantize()
            .mse(&w);
        assert!(e < e_bnb, "sinq-nf4 {e:.3e} vs bnb-nf4 {e_bnb:.3e}");
    }

    #[test]
    fn property_random_shapes_never_panic_and_improve() {
        let mut rng = Rng::new(68);
        for _ in 0..10 {
            let rows = 8 + rng.below(64);
            let cols = 16 + rng.below(128);
            let w = llm_like(rows, cols, rng.next_u64());
            let q = quantize(&w, &QuantConfig::new(Method::Sinq, 4).with_group(32));
            assert_eq!(q.codes.len(), rows * cols);
            let e_sinq = q.dequantize().mse(&w);
            let e_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 4).with_group(32))
                .dequantize()
                .mse(&w);
            // Not guaranteed per-instance, but should hold overwhelmingly;
            // allow a small slack factor.
            assert!(
                e_sinq < e_rtn * 1.2,
                "rows={rows} cols={cols}: {e_sinq:.3e} vs {e_rtn:.3e}"
            );
        }
    }
}
