//! HQQ — Half-Quadratic Quantization (Badri & Shaji, 2023).
//!
//! Starting from the RTN solution, HQQ refines the per-group *shift* so that
//! the reconstruction minimizes an outlier-robust p-norm (`p = 0.7` by
//! default) instead of the implicit ∞/2-norm of min/max RTN. The solver is a
//! half-quadratic split: introduce `W_e ≈ W − dq(q(W))`, alternate a
//! generalized soft-threshold on `W_e` (the proximal operator of ‖·‖_p^p)
//! with a closed-form mean update of the shift, annealing the coupling β.
//! This matches the reference implementation's `optimize_weights_proximal`.

use super::{apply_aux_precision, rtn, QuantConfig, QuantizedLinear};
use crate::tensor::Matrix;

/// Generalized soft-thresholding: prox of `‖x‖_p^p / β`.
#[inline]
fn shrink_lp(x: f32, beta: f32, p: f32) -> f32 {
    if x == 0.0 {
        return 0.0;
    }
    let thresh = (1.0 / beta) * x.abs().powf(p - 1.0);
    x.signum() * (x.abs() - thresh).max(0.0)
}

/// Refine shifts of an RTN-initialized quantization of `w_hat` (one group).
///
/// `codes`/`scale`/`z` are the group's RTN output; returns the refined shift
/// and codes. The scale stays fixed (as in reference HQQ).
fn optimize_group(
    w: &[f32],
    scale: f32,
    z0: f32,
    maxq: f32,
    iters: usize,
    p: f32,
) -> (f32, Vec<u8>) {
    let inv_s = 1.0 / scale;
    let mut z = z0;
    let mut beta = 10.0f32;
    let kappa = 1.01f32;
    let mut codes: Vec<u8> = Vec::new();
    let mut best = (f32::INFINITY, z0, Vec::new());
    for _ in 0..iters {
        // Quantize with current shift.
        codes = w.iter().map(|&v| (v * inv_s - z).round().clamp(0.0, maxq) as u8).collect();
        // Dequantized reconstruction and p-norm error.
        let mut err = 0.0f32;
        let rec: Vec<f32> = codes.iter().map(|&q| scale * (q as f32 + z)).collect();
        for (&v, &r) in w.iter().zip(&rec) {
            err += (v - r).abs().powf(p);
        }
        if err < best.0 {
            best = (err, z, codes.clone());
        }
        // W_e ← shrink(W − W_r); z ← mean(Q − (W − W_e)/s).
        let mut zsum = 0.0f32;
        for ((&v, &r), &q) in w.iter().zip(&rec).zip(&codes) {
            let e = shrink_lp(v - r, beta, p);
            zsum += q as f32 - (v - e) * inv_s;
        }
        z = zsum / w.len() as f32;
        beta *= kappa;
    }
    // Return the best-seen shift (reference keeps last; best is safer).
    if best.0.is_finite() {
        (best.1, best.2)
    } else {
        (z, codes)
    }
}

/// HQQ quantization of a matrix: RTN init per (row, group), then proximal
/// shift refinement. Uniform grids only (HQQ is defined on integer grids).
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    assert!(cfg.grid.is_uniform(), "HQQ requires a uniform grid");
    let maxq = (cfg.grid.size() - 1) as f32;
    let g = cfg.group_size;
    let n_groups = w.cols.div_ceil(g);

    let mut codes = vec![0u8; w.rows * w.cols];
    let mut scales = Matrix::zeros(w.rows, n_groups);
    let mut shifts = Matrix::zeros(w.rows, n_groups);

    for i in 0..w.rows {
        let row = w.row(i);
        for gi in 0..n_groups {
            let j0 = gi * g;
            let j1 = (j0 + g).min(w.cols);
            let init = rtn::quantize_group(&row[j0..j1], &cfg.grid, true);
            let (z, cs) =
                optimize_group(&row[j0..j1], init.scale, init.shift, maxq, cfg.hqq_iters, cfg.hqq_p);
            *scales.at_mut(i, gi) = init.scale;
            *shifts.at_mut(i, gi) = z;
            codes[i * w.cols + j0..i * w.cols + j1].copy_from_slice(&cs);
        }
    }
    apply_aux_precision(&mut scales, cfg.aux);
    apply_aux_precision(&mut shifts, cfg.aux);
    QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: g,
        grid: cfg.grid.clone(),
        codes,
        scales,
        shifts: Some(shifts),
        col_scale: None,
        hadamard: false,
        hadamard_out: false,
        pair_codebook: None,
        aux: cfg.aux,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{Method, QuantConfig};

    fn pnorm_err(w: &Matrix, q: &QuantizedLinear, p: f32) -> f64 {
        let deq = q.dequantize();
        w.data
            .iter()
            .zip(&deq.data)
            .map(|(&a, &b)| ((a - b).abs() as f64).powf(p as f64))
            .sum()
    }

    #[test]
    fn hqq_improves_pnorm_over_rtn() {
        let w = llm_like(32, 128, 81);
        let cfg_rtn = QuantConfig::new(Method::Rtn, 4);
        let cfg_hqq = QuantConfig::new(Method::Hqq, 4);
        let e_rtn = pnorm_err(&w, &rtn::quantize(&w, &cfg_rtn), 0.7);
        let e_hqq = pnorm_err(&w, &quantize(&w, &cfg_hqq), 0.7);
        assert!(e_hqq < e_rtn, "hqq {e_hqq:.4} vs rtn {e_rtn:.4}");
    }

    #[test]
    fn hqq_3bit_also_improves() {
        let w = llm_like(32, 128, 82);
        let e_rtn = pnorm_err(&w, &rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 3)), 0.7);
        let e_hqq = pnorm_err(&w, &quantize(&w, &QuantConfig::new(Method::Hqq, 3)), 0.7);
        assert!(e_hqq < e_rtn);
    }

    #[test]
    fn shrink_lp_properties() {
        // Shrinks magnitude, keeps sign, and is monotone in beta.
        assert_eq!(shrink_lp(0.0, 10.0, 0.7), 0.0);
        let x = 0.5f32;
        let a = shrink_lp(x, 5.0, 0.7);
        let b = shrink_lp(x, 50.0, 0.7);
        assert!(a >= 0.0 && a <= x);
        assert!(b > a, "larger beta shrinks less");
        assert_eq!(shrink_lp(-x, 5.0, 0.7), -a);
    }

    #[test]
    fn codes_stay_in_range() {
        let w = llm_like(16, 64, 83);
        let q = quantize(&w, &QuantConfig::new(Method::Hqq, 4));
        assert!(q.codes.iter().all(|&c| c < 16));
    }

    #[test]
    fn hqq_slower_but_still_bounded_mse() {
        let w = llm_like(16, 64, 84);
        let q = quantize(&w, &QuantConfig::new(Method::Hqq, 4));
        let rel = q.dequantize().mse(&w)
            / (w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.numel() as f64);
        assert!(rel < 0.05, "relative mse {rel}");
    }
}
