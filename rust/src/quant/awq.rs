//! AWQ — activation-aware weight quantization (Lin et al., 2024), Eq. 6 —
//! and **A-SINQ**, the paper's combination of SINQ normalization with AWQ
//! calibration (§2.2.2).
//!
//! AWQ searches a single per-layer exponent `α` so that scaling columns by
//! `μ_x^α` before quantization minimizes the layer-output reconstruction
//! error on a calibration sample. A-SINQ first runs Algorithm 1, then the AWQ
//! search on the normalized matrix, using a **1-norm** objective (the paper's
//! footnote 1: slightly better in combination with SINQ).

use super::{apply_aux_precision, rtn, sinq, Calibration, QuantConfig, QuantizedLinear};
use crate::tensor::Matrix;
use crate::util::half::round_f16;

/// AWQ column scales for exponent `alpha`: `c_j = μ_j^α`, normalized as in
/// the reference implementation (`c ← c / sqrt(max·min)`) so the scale is
/// centered around 1.
pub fn awq_scales(mu_x: &[f32], alpha: f32) -> Vec<f32> {
    let mut c: Vec<f32> = mu_x.iter().map(|&m| m.max(1e-8).powf(alpha)).collect();
    let hi = c.iter().cloned().fold(f32::MIN, f32::max);
    let lo = c.iter().cloned().fold(f32::MAX, f32::min);
    let norm = (hi * lo).sqrt().max(1e-8);
    for v in &mut c {
        *v /= norm;
        *v = v.clamp(1e-4, 1e4);
    }
    c
}

/// Output reconstruction error `‖X·Wᵀ − X·Ŵᵀ‖` on the calibration sample;
/// `p1 = true` uses the 1-norm (A-SINQ variant), else squared 2-norm.
fn output_err(x: &Matrix, w: &Matrix, w_hat: &Matrix, p1: bool) -> f64 {
    let y = x.matmul_nt(w);
    let y_hat = x.matmul_nt(w_hat);
    if p1 {
        y.data.iter().zip(&y_hat.data).map(|(&a, &b)| (a - b).abs() as f64).sum()
    } else {
        y.data
            .iter()
            .zip(&y_hat.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }
}

/// Quantize with column pre-scale `c`, returning (layer, effective weight).
/// The stored `col_scale` is `base_t ⊘ c` so dequantization reproduces
/// `s ⊙ (Q+z) ⊘ c ⊙ base_t` directly.
fn quantize_with_colscale(
    w_space: &Matrix, // matrix to quantize (already in normalized space)
    c: &[f32],
    base_t: Option<&[f32]>,
    row_merge: Option<&[f32]>,
    cfg: &QuantConfig,
) -> QuantizedLinear {
    let mut ws = w_space.clone();
    ws.scale_cols(c);
    let (codes, mut scales, mut shifts) =
        rtn::quantize_grouped(&ws, &cfg.grid, cfg.group_size, cfg.shift);
    if let Some(s_row) = row_merge {
        for i in 0..scales.rows {
            for g in 0..scales.cols {
                *scales.at_mut(i, g) *= s_row[i];
            }
        }
    }
    apply_aux_precision(&mut scales, cfg.aux);
    if let Some(z) = shifts.as_mut() {
        apply_aux_precision(z, cfg.aux);
    }
    let t: Vec<f32> = (0..c.len())
        .map(|j| round_f16(base_t.map(|b| b[j]).unwrap_or(1.0) / c[j]))
        .collect();
    QuantizedLinear {
        rows: w_space.rows,
        cols: w_space.cols,
        group_size: cfg.group_size,
        grid: cfg.grid.clone(),
        codes,
        scales,
        shifts,
        col_scale: Some(t),
        hadamard: false,
        hadamard_out: false,
        pair_codebook: None,
        aux: cfg.aux,
    }
}

/// Plain AWQ (Eq. 6): grid-search α ∈ {0, 1/n, …, 1} minimizing the 2-norm
/// output error; the winning scale becomes the (inverted) column scale.
pub fn quantize(w: &Matrix, cfg: &QuantConfig, calib: &Calibration) -> QuantizedLinear {
    search_alpha(w, cfg, calib, None, None, false)
}

/// A-SINQ (§2.2.2): Algorithm 1 normalization, then the AWQ α-search on the
/// normalized matrix with a 1-norm objective; row scales merge into group
/// scales, column scales compose (`t_sinq ⊘ μ^α`).
pub fn quantize_asinq(w: &Matrix, cfg: &QuantConfig, calib: &Calibration) -> QuantizedLinear {
    let sk = sinq::sinkhorn_normalize(w, cfg.sinq_iters, cfg.sinq_clamp);
    let mut w_hat = w.clone();
    w_hat.div_rows(&sk.row);
    w_hat.div_cols(&sk.col);
    // In normalized space the *effective* weight must still approximate W:
    // W ≈ s ⊙ dq(Ŵ·c) ⊘ c ⊙ t. The α-search evaluates that composition.
    search_alpha(&w_hat, cfg, calib, Some(&sk.col), Some(&sk.row), true)
}

fn search_alpha(
    w_space: &Matrix,
    cfg: &QuantConfig,
    calib: &Calibration,
    base_t: Option<&[f32]>,
    row_merge: Option<&[f32]>,
    p1: bool,
) -> QuantizedLinear {
    // The original-space weight (for the reference output Y = X·Wᵀ).
    let w_orig = {
        let mut m = w_space.clone();
        if let Some(s) = row_merge {
            m.scale_rows(s);
        }
        if let Some(t) = base_t {
            m.scale_cols(t);
        }
        m
    };
    let mut best: Option<(f64, QuantizedLinear)> = None;
    for step in 0..=cfg.awq_grid {
        let alpha = step as f32 / cfg.awq_grid as f32;
        let c = awq_scales(&calib.mu_x, alpha);
        let q = quantize_with_colscale(w_space, &c, base_t, row_merge, cfg);
        let w_eff = q.dequantize();
        let err = output_err(&calib.x, &w_orig, &w_eff, p1);
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, q));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::llm_like;
    use crate::quant::{Method, QuantConfig};
    use crate::tensor::Rng;

    /// Calibration inputs whose per-column magnitude *matches* the column
    /// structure of the weights (the correlation the paper establishes).
    fn calib_for(w: &Matrix, seed: u64) -> Calibration {
        let col_stds = crate::tensor::stats::col_stds(w);
        let mut rng = Rng::new(seed);
        let mut x = Matrix::from_fn(32, w.cols, |_, _| rng.normal_f32(0.0, 1.0));
        // Input scale ∝ 1/σ_col² so the product has strong column variation.
        let t: Vec<f32> = col_stds.iter().map(|&s| (0.02 / s.max(1e-6)) as f32).collect();
        x.scale_cols(&t);
        Calibration::from_activations(x)
    }

    #[test]
    fn awq_scales_normalized_around_one() {
        let mu = vec![0.1f32, 1.0, 10.0];
        let c = awq_scales(&mu, 0.5);
        // geometric centering: max·min == 1
        let hi = c.iter().cloned().fold(f32::MIN, f32::max);
        let lo = c.iter().cloned().fold(f32::MAX, f32::min);
        assert!((hi * lo - 1.0).abs() < 1e-4);
        // alpha = 0 ⇒ all ones
        let c0 = awq_scales(&mu, 0.0);
        assert!(c0.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn awq_beats_rtn_on_activation_error() {
        let w = llm_like(48, 128, 91);
        let calib = calib_for(&w, 911);
        let cfg = QuantConfig::new(Method::Awq, 3);
        let q_awq = quantize(&w, &cfg, &calib);
        let q_rtn = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 3));
        let e_awq = output_err(&calib.x, &w, &q_awq.dequantize(), false);
        let e_rtn = output_err(&calib.x, &w, &q_rtn.dequantize(), false);
        assert!(e_awq < e_rtn, "awq {e_awq:.4e} vs rtn {e_rtn:.4e}");
    }

    #[test]
    fn asinq_beats_plain_awq_or_close() {
        let w = llm_like(48, 128, 92);
        let calib = calib_for(&w, 921);
        let q_awq = quantize(&w, &QuantConfig::new(Method::Awq, 3), &calib);
        let q_asinq = quantize_asinq(&w, &QuantConfig::new(Method::ASinq, 3), &calib);
        let e_awq = output_err(&calib.x, &w, &q_awq.dequantize(), false);
        let e_asinq = output_err(&calib.x, &w, &q_asinq.dequantize(), false);
        // A-SINQ should not be materially worse; usually better.
        assert!(e_asinq < e_awq * 1.1, "asinq {e_asinq:.4e} vs awq {e_awq:.4e}");
    }

    #[test]
    fn asinq_effective_weight_approximates_original() {
        let w = llm_like(16, 64, 93);
        let calib = calib_for(&w, 931);
        let q = quantize_asinq(&w, &QuantConfig::new(Method::ASinq, 4), &calib);
        let rel = q.dequantize().mse(&w)
            / (w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.numel() as f64);
        assert!(rel < 0.05, "relative mse {rel}");
    }

    #[test]
    fn alpha_search_covers_endpoints() {
        // With a constant μ_x the scales are 1 for every α: AWQ ≡ RTN.
        let w = llm_like(8, 64, 94);
        let x = Matrix::from_fn(8, 64, |_, _| 1.0);
        let calib = Calibration::from_activations(x);
        let q = quantize(&w, &QuantConfig::new(Method::Awq, 4), &calib);
        let r = rtn::quantize(&w, &QuantConfig::new(Method::Rtn, 4));
        assert!(q.dequantize().dist(&r.dequantize()) < 1e-4);
    }
}
