//! Forward / decode executors over the AOT artifacts.
//!
//! Weights are uploaded to device buffers **once** per model variant; the
//! request path transfers only tokens (and the KV cache buffer stays on
//! device between steps in the serving loop — functional update in, buffer
//! out).

use std::sync::Arc;

use crate::backend::InferenceBackend;
use crate::eval::LogitsEngine;
use crate::model::ModelConfig;
use crate::quant::QuantizedLinear;
use crate::runtime::client::{self, PjrtRuntime};
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use xla::{ElementType, PjRtBuffer, PjRtLoadedExecutable};

/// Shape of the full-sequence forward artifact (matches `aot.py`).
pub const FWD_BATCH: usize = 4;
pub const FWD_SEQ: usize = 128;
/// KV capacity of the decode artifacts.
pub const DECODE_CTX: usize = 768;

/// Full-sequence forward through `fwd_{model}.hlo.txt`; implements
/// [`LogitsEngine`] (single sequence) plus a batched entry point.
pub struct PjrtForward {
    exe: Arc<PjRtLoadedExecutable>,
    weight_buffers: Vec<PjRtBuffer>,
    cfg: ModelConfig,
}

impl PjrtForward {
    /// Build from effective f32 weights (any quantization method).
    pub fn new(
        rt: &PjrtRuntime,
        cfg: &ModelConfig,
        weights: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
    ) -> anyhow::Result<PjrtForward> {
        let exe = rt.load(&format!("fwd_{}.hlo.txt", cfg.name))?;
        let mut weight_buffers = Vec::new();
        for name in cfg.weight_names() {
            let buf = if let Some(m) = weights.get(&name) {
                rt.client.buffer_from_host_buffer::<f32>(&m.data, &[m.rows, m.cols], None)
            } else if let Some(v) = vectors.get(&name) {
                rt.client.buffer_from_host_buffer::<f32>(v, &[v.len()], None)
            } else {
                anyhow::bail!("missing weight '{name}'");
            }
            .map_err(|e| anyhow::anyhow!("upload {name}: {e}"))?;
            weight_buffers.push(buf);
        }
        Ok(PjrtForward { exe, weight_buffers, cfg: cfg.clone() })
    }

    /// Batched forward: up to [`FWD_BATCH`] sequences of ≤ [`FWD_SEQ`] tokens;
    /// returns per-sequence logits (seq_len, vocab).
    pub fn forward_batch(&self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        anyhow::ensure!(!seqs.is_empty() && seqs.len() <= FWD_BATCH, "bad batch size");
        anyhow::ensure!(seqs.iter().all(|s| s.len() <= FWD_SEQ), "sequence too long");
        let mut tokens = vec![0i32; FWD_BATCH * FWD_SEQ];
        for (b, s) in seqs.iter().enumerate() {
            for (p, &tok) in s.iter().enumerate() {
                tokens[b * FWD_SEQ + p] = tok as i32;
            }
        }
        let tok_buf = self
            .exe
            .client()
            .buffer_from_host_buffer::<i32>(&tokens, &[FWD_BATCH, FWD_SEQ], None)
            .map_err(|e| anyhow::anyhow!("token upload: {e}"))?;

        let mut args: Vec<&PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_buffers.iter());
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute fwd: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        let data = client::literal_to_f32(&out)?;
        let v = self.cfg.vocab;
        anyhow::ensure!(data.len() == FWD_BATCH * FWD_SEQ * v, "bad logits size");
        Ok(seqs
            .iter()
            .enumerate()
            .map(|(b, s)| {
                let mut m = Matrix::zeros(s.len(), v);
                for p in 0..s.len() {
                    let off = (b * FWD_SEQ + p) * v;
                    m.row_mut(p).copy_from_slice(&data[off..off + v]);
                }
                m
            })
            .collect())
    }
}

impl LogitsEngine for PjrtForward {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        let mut out = self.forward_batch(&[tokens])?;
        Ok(out.pop().unwrap())
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

impl InferenceBackend for PjrtForward {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        FWD_BATCH
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        // Dispatch to the inherent batched entry point.
        PjrtForward::forward_batch(self, seqs)
    }
}

/// Autoregressive decoder over `decode_{model}[_w4].hlo.txt`: the KV cache
/// lives on device; each step transfers one token in and one logits row out.
pub struct PjrtDecoder {
    exe: Arc<PjRtLoadedExecutable>,
    weight_buffers: Vec<PjRtBuffer>,
    kv: Option<PjRtBuffer>,
    cfg: ModelConfig,
    pub pos: usize,
}

impl PjrtDecoder {
    /// FP (f32) decoder — the W16A16 baseline of Table 6.
    pub fn new_fp(
        rt: &PjrtRuntime,
        cfg: &ModelConfig,
        weights: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
    ) -> anyhow::Result<PjrtDecoder> {
        let exe = rt.load(&format!("decode_{}.hlo.txt", cfg.name))?;
        let mut bufs = Vec::new();
        for name in cfg.weight_names() {
            let buf = if let Some(m) = weights.get(&name) {
                rt.client.buffer_from_host_buffer::<f32>(&m.data, &[m.rows, m.cols], None)
            } else {
                let v = &vectors[&name];
                rt.client.buffer_from_host_buffer::<f32>(v, &[v.len()], None)
            }
            .map_err(|e| anyhow::anyhow!("upload {name}: {e}"))?;
            bufs.push(buf);
        }
        Self::finish(rt, exe, bufs, cfg)
    }

    /// W4A16 decoder — quantized operands feed the Pallas dequant-matmul
    /// graph (Table 6's SINQ row).
    pub fn new_w4(
        rt: &PjrtRuntime,
        cfg: &ModelConfig,
        qlayers: &BTreeMap<String, QuantizedLinear>,
        fweights: &BTreeMap<String, Matrix>,
        fvectors: &BTreeMap<String, Vec<f32>>,
    ) -> anyhow::Result<PjrtDecoder> {
        let exe = rt.load(&format!("decode_{}_w4.hlo.txt", cfg.name))?;
        let qnames = cfg.quantizable_names();
        let mut bufs = Vec::new();
        // f-weights first (artifact argument order: fnames then per-q 4-tuple).
        for name in cfg.weight_names().iter().filter(|n| !qnames.contains(n)) {
            let buf = if let Some(m) = fweights.get(name.as_str()) {
                rt.client.buffer_from_host_buffer::<f32>(&m.data, &[m.rows, m.cols], None)
            } else {
                let v = &fvectors[name.as_str()];
                rt.client.buffer_from_host_buffer::<f32>(v, &[v.len()], None)
            }
            .map_err(|e| anyhow::anyhow!("upload {name}: {e}"))?;
            bufs.push(buf);
        }
        for name in &qnames {
            let q = qlayers
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("missing quantized layer {name}"))?;
            anyhow::ensure!(q.grid.is_uniform(), "W4 artifact expects uniform codes");
            let cl = rt
                .client
                .buffer_from_host_raw_bytes(ElementType::S8, &q.codes, &[q.rows, q.cols], None)
                .map_err(|e| anyhow::anyhow!("codes {name}: {e}"))?;
            bufs.push(cl);
            let s = &q.scales;
            bufs.push(
                rt.client
                    .buffer_from_host_buffer::<f32>(&s.data, &[s.rows, s.cols], None)
                    .map_err(|e| anyhow::anyhow!("scales {name}: {e}"))?,
            );
            let zero = Matrix::zeros(s.rows, s.cols);
            let z = q.shifts.as_ref().unwrap_or(&zero);
            bufs.push(
                rt.client
                    .buffer_from_host_buffer::<f32>(&z.data, &[z.rows, z.cols], None)
                    .map_err(|e| anyhow::anyhow!("shifts {name}: {e}"))?,
            );
            let ones = vec![1.0f32; q.cols];
            let t = q.col_scale.as_deref().unwrap_or(&ones);
            bufs.push(
                rt.client
                    .buffer_from_host_buffer::<f32>(t, &[q.cols], None)
                    .map_err(|e| anyhow::anyhow!("t {name}: {e}"))?,
            );
        }
        Self::finish(rt, exe, bufs, cfg)
    }

    fn finish(
        rt: &PjrtRuntime,
        exe: Arc<PjRtLoadedExecutable>,
        weight_buffers: Vec<PjRtBuffer>,
        cfg: &ModelConfig,
    ) -> anyhow::Result<PjrtDecoder> {
        let kv_len = cfg.layers * 2 * cfg.heads * DECODE_CTX * cfg.head_dim();
        let kv = rt
            .client
            .buffer_from_host_buffer::<f32>(
                &vec![0.0f32; kv_len],
                &[cfg.layers, 2, 1, cfg.heads, DECODE_CTX, cfg.head_dim()],
                None,
            )
            .map_err(|e| anyhow::anyhow!("kv init: {e}"))?;
        Ok(PjrtDecoder { exe, weight_buffers, kv: Some(kv), cfg: cfg.clone(), pos: 0 })
    }

    /// Feed one token; returns the next-token logits. The KV buffer is
    /// threaded functionally on device.
    pub fn step(&mut self, token: u8) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.pos < DECODE_CTX, "context exhausted");
        let client = self.exe.client().clone();
        let tok = client
            .buffer_from_host_buffer::<i32>(&[token as i32], &[1], None)
            .map_err(|e| anyhow::anyhow!("token: {e}"))?;
        let pos = client
            .buffer_from_host_buffer::<i32>(&[self.pos as i32], &[], None)
            .map_err(|e| anyhow::anyhow!("pos: {e}"))?;
        let kv = self.kv.take().expect("kv buffer present");
        let mut args: Vec<&PjRtBuffer> = vec![&tok, &pos, &kv];
        args.extend(self.weight_buffers.iter());
        let mut result =
            self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("decode step: {e}"))?;
        // Output is a 2-tuple (logits, kv'): returned as one tuple buffer.
        let outs = result.pop().unwrap();
        anyhow::ensure!(!outs.is_empty(), "empty execution result");
        // The decode artifact returns ONE flat f32 vector `[logits | kv']`:
        // multi-element tuple outputs cannot be fetched through
        // xla_extension 0.5.1's ToLiteralSync, and feeding an execution's
        // output buffer straight back as an input deadlocks the TFRT CPU
        // client — so the KV cache round-trips the host each step (sub-ms at
        // family sizes; quantified in EXPERIMENTS.md §Perf).
        let lit = outs[0].to_literal_sync().map_err(|e| anyhow::anyhow!("{e}"))?;
        let flat = lit.to_tuple1().map_err(|e| anyhow::anyhow!("tuple1: {e}"))?;
        let data = client::literal_to_f32(&flat)?;
        let v = self.cfg.vocab;
        anyhow::ensure!(data.len() > v, "flat decode output too small");
        let cfg = &self.cfg;
        let kv_dims =
            [cfg.layers, 2, 1, cfg.heads, DECODE_CTX, cfg.head_dim()];
        let new_kv = client
            .buffer_from_host_buffer::<f32>(&data[v..], &kv_dims, None)
            .map_err(|e| anyhow::anyhow!("kv reupload: {e}"))?;
        self.kv = Some(new_kv);
        self.pos += 1;
        Ok(data[..v].to_vec())
    }

    /// Greedy generation helper for the serving bench: prefill `prompt`,
    /// then generate `n` tokens; returns (generated, total_steps).
    pub fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        let mut last = Vec::new();
        for &t in prompt {
            last = self.step(t)?;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = argmax(&last) as u8;
            out.push(next);
            last = self.step(next)?;
        }
        Ok(out)
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}
