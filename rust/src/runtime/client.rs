//! PJRT client wrapper + executable cache + literal marshalling.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::tensor::Matrix;
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// A process-wide PJRT CPU runtime with an executable cache. Compilation of
/// an HLO artifact happens once; subsequent loads hit the cache (the
/// serving coordinator compiles per (graph, shape) variant, like any
/// inference server's warmup).
pub struct PjrtRuntime {
    pub client: PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<PjRtLoadedExecutable>>>,
    pub art_dir: PathBuf,
}

impl PjrtRuntime {
    pub fn cpu(art_dir: impl AsRef<Path>) -> anyhow::Result<PjrtRuntime> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(PjrtRuntime {
            client,
            cache: Mutex::new(HashMap::new()),
            art_dir: art_dir.as_ref().to_path_buf(),
        })
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, artifact: &str) -> anyhow::Result<Arc<PjRtLoadedExecutable>> {
        let path = self.art_dir.join(artifact);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&path) {
                return Ok(exe.clone());
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e} (run `make artifacts`)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables resident.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------- literals --

/// f32 literal from a matrix (row-major (rows, cols)).
pub fn lit_matrix(m: &Matrix) -> anyhow::Result<Literal> {
    let bytes: Vec<u8> = m.data.iter().flat_map(|v| v.to_le_bytes()).collect();
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &[m.rows, m.cols], &bytes)
        .map_err(|e| anyhow::anyhow!("lit_matrix: {e}"))
}

/// f32 literal from a vector.
pub fn lit_vec(v: &[f32]) -> anyhow::Result<Literal> {
    let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &[v.len()], &bytes)
        .map_err(|e| anyhow::anyhow!("lit_vec: {e}"))
}

/// f32 literal of arbitrary shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> anyhow::Result<Literal> {
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("lit_f32: {e}"))
}

/// i32 literal of arbitrary shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> anyhow::Result<Literal> {
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("lit_i32: {e}"))
}

/// i32 scalar literal.
pub fn lit_i32_scalar(v: i32) -> anyhow::Result<Literal> {
    Literal::create_from_shape_and_untyped_data(ElementType::S32, &[], &v.to_le_bytes())
        .map_err(|e| anyhow::anyhow!("lit_i32_scalar: {e}"))
}

/// i8 literal from quantization codes.
pub fn lit_i8(shape: &[usize], codes: &[u8]) -> anyhow::Result<Literal> {
    // Codes are 0..=15 so the u8→i8 reinterpretation is value-preserving.
    Literal::create_from_shape_and_untyped_data(ElementType::S8, shape, codes)
        .map_err(|e| anyhow::anyhow!("lit_i8: {e}"))
}

/// Extract an f32 tensor from a result literal.
pub fn literal_to_f32(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec<f32>: {e}"))
}

#[cfg(all(test, feature = "pjrt-artifacts"))]
mod tests {
    use super::*;

    // These tests exercise the real PJRT client; they are cheap (tiny
    // computations) but do initialize XLA — hence the `pjrt-artifacts`
    // gate (the default build links the vendored xla stub).

    #[test]
    fn literal_round_trips() {
        let m = Matrix::from_vec(2, 3, vec![1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]);
        let lit = lit_matrix(&m).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(literal_to_f32(&lit).unwrap(), m.data);

        let lit = lit_i32(&[4], &[-1, 0, 7, 1 << 30]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![-1, 0, 7, 1 << 30]);

        let lit = lit_i8(&[3], &[0, 7, 15]).unwrap();
        assert_eq!(lit.to_vec::<i8>().unwrap(), vec![0, 7, 15]);
    }

    #[test]
    fn scalar_literal() {
        let lit = lit_i32_scalar(42).unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 42);
    }
}
