//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the XLA CPU client — Python is never on this path.
//!
//! * [`client`] — `PjrtRuntime`: PJRT client + executable cache keyed by
//!   artifact path, literal marshalling helpers.
//! * [`exec`] — `PjrtForward` / `PjrtDecoder`: the forward-pass and
//!   decode-step wrappers implementing [`crate::eval::LogitsEngine`] /
//!   [`crate::backend::InferenceBackend`] and the serving loop, with
//!   weights kept resident as device buffers.
//!
//! In offline builds the `xla` dependency is a vendored stub: this module
//! compiles everywhere but every PJRT entry point errors at runtime, and
//! serving/eval fall back to `--backend native`
//! ([`crate::backend::NativeBackend`]). Link a real xla_extension binding
//! and build with `--features pjrt-artifacts` to exercise this path.

pub mod client;
pub mod exec;

pub use client::PjrtRuntime;
pub use exec::{PjrtDecoder, PjrtForward};
