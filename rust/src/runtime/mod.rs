//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the XLA CPU client — Python is never on this path.
//!
//! * [`client`] — `PjrtRuntime`: PJRT client + executable cache keyed by
//!   artifact path, literal marshalling helpers.
//! * [`exec`] — `PjrtForward` / `PjrtDecoder`: the forward-pass and
//!   decode-step wrappers implementing [`crate::eval::LogitsEngine`] and the
//!   serving loop, with weights kept resident as device buffers.

pub mod client;
pub mod exec;

pub use client::PjrtRuntime;
pub use exec::{PjrtDecoder, PjrtForward};
