//! # SINQ — Sinkhorn-Normalized Quantization (full-system reproduction)
//!
//! This crate reproduces *SINQ: Sinkhorn-Normalized Quantization for
//! Calibration-Free Low-Precision LLM Weights* (Muller et al., 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a quantization pipeline (per-layer
//!   job scheduler over a thread pool), a serving/eval runtime with
//!   **pluggable inference backends**, the full quantizer zoo
//!   (RTN/HQQ/SINQ/Hadamard/AWQ/A-SINQ/GPTQ/CrossQuant/codebook/GGUF), and a
//!   CLI that regenerates every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX transformer whose forward
//!   graph is lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels (Sinkhorn
//!   normalization, RTN quantize, fused dequant-matmul) called from L2.
//!
//! ## Inference backends
//!
//! Serving and evaluation dispatch through the
//! [`backend::InferenceBackend`] trait, selected by `--backend` on the CLI:
//!
//! * [`backend::NativeBackend`] (**default**) — a pure-Rust engine that
//!   executes **directly on bit-packed quantized weights**: fused
//!   dequantize-matmul/matvec kernels (the CPU analogue of the L1 Pallas
//!   `dequant_matmul`) whose unpack/LUT-decode/dot inner loops dispatch to
//!   runtime-selected AVX2/NEON implementations ([`backend::simd`], with
//!   scalar as fallback and parity oracle), a preallocated-KV-cache decoder
//!   for `generate`, a continuous-batching [`backend::BatchDecoder`] that
//!   serves many generations through one shared weight-tile unpack per
//!   step, and thread-pool parallel tiles. Runs on any box: no artifacts,
//!   no XLA, no Python.
//! * [`runtime::PjrtForward`] (`--backend pjrt`) — executes the AOT-compiled
//!   XLA artifacts via PJRT. After `make artifacts` the `sinq` binary covers
//!   the full paper evaluation through this path. (In offline builds the
//!   `xla` dependency is a vendored stub that errors at runtime; see
//!   `rust/Cargo.toml`.)
//!
//! The [`serve`] module exposes the native engine over the network:
//! `sinq serve --listen ADDR:PORT` runs a dependency-free HTTP/1.1 + SSE
//! endpoint (streamed `POST /v1/generate`, batched `POST /v1/score`,
//! `GET /healthz`, Prometheus `GET /metrics`) over the continuous-batching
//! decoder.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod backend;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fmt;
pub mod model;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
