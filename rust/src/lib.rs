//! # SINQ — Sinkhorn-Normalized Quantization (full-system reproduction)
//!
//! This crate reproduces *SINQ: Sinkhorn-Normalized Quantization for
//! Calibration-Free Low-Precision LLM Weights* (Muller et al., 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a quantization pipeline (per-layer
//!   job scheduler over a thread pool), a serving/eval runtime that executes
//!   AOT-compiled XLA artifacts via PJRT, the full quantizer zoo
//!   (RTN/HQQ/SINQ/Hadamard/AWQ/A-SINQ/GPTQ/CrossQuant/codebook/GGUF), and a
//!   CLI that regenerates every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX transformer whose forward
//!   graph is lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels (Sinkhorn
//!   normalization, RTN quantize, fused dequant-matmul) called from L2.
//!
//! Python never runs on the request path: after `make artifacts` the `sinq`
//! binary is self-contained.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fmt;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;
