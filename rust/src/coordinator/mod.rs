//! L3 coordinator: the systems layer that turns the quantizer zoo into a
//! deployable pipeline.
//!
//! * [`scheduler`] — per-layer quantization jobs over the worker pool, with
//!   activation-capture pre-pass for calibrated methods and progress
//!   reporting.
//! * [`pipeline`] — load checkpoint → (optional no-overhead fold) →
//!   quantize → pack → save; plus the PJRT-accelerated Algorithm-1 path
//!   that runs the Pallas `sinq_quantize` artifacts.
//! * [`server`] — the serving coordinator: request router + dynamic batcher
//!   in front of any [`crate::backend::InferenceBackend`] — the PJRT
//!   artifact executor or the native fused-kernel engine
//!   (vLLM-router-shaped, scaled to one box).

pub mod pipeline;
pub mod scheduler;
pub mod server;
