//! End-to-end quantization pipeline: load → (fold) → quantize → save,
//! plus the quantize-and-serve path (straight into a native inference
//! backend) and the PJRT-accelerated Algorithm-1 path.

use std::path::Path;
use std::time::Instant;

use crate::backend::{EngineConfig, NativeBackend};
use crate::coordinator::scheduler::{self, ScheduleOpts};
use crate::model::{fold, ModelWeights, QuantizedModel};
use crate::quant::{QuantConfig, QuantizedLinear};
use crate::runtime::client::{self, PjrtRuntime};
use crate::tensor::Matrix;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    pub schedule: ScheduleOpts,
    /// No-overhead SINQ: fold shared column scales into producers first and
    /// quantize single-scale (§2.3.1).
    pub no_overhead: bool,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts { schedule: ScheduleOpts::default(), no_overhead: false }
    }
}

/// Run the full pipeline; returns the quantized model and wall time (s).
pub fn run(
    mw: &ModelWeights,
    qcfg: &QuantConfig,
    opts: &PipelineOpts,
) -> anyhow::Result<(QuantizedModel, f64)> {
    run_traced(mw, qcfg, opts).map(|(qm, secs, _)| (qm, secs))
}

/// [`run`], also returning the scheduler's per-layer quality reports so the
/// quantize-and-serve path can surface a [`crate::obs::QuantReport`].
pub fn run_traced(
    mw: &ModelWeights,
    qcfg: &QuantConfig,
    opts: &PipelineOpts,
) -> anyhow::Result<(QuantizedModel, f64, Vec<scheduler::JobReport>)> {
    let t0 = Instant::now();
    let (qm, reports) = if opts.no_overhead {
        let folded = fold::fold_model(mw, qcfg.sinq_iters, qcfg.sinq_clamp);
        let mut base = qcfg.clone();
        base.method = crate::quant::Method::Rtn; // t already absorbed
        let (mut qm, reports) = scheduler::quantize_model(&folded, &base, &opts.schedule)?;
        qm.method = format!("{}-no-overhead", qcfg.method.name());
        // The folded norm gains / producer weights are part of the model.
        qm.fvectors = folded.vectors.clone();
        (qm, reports)
    } else {
        scheduler::quantize_model(mw, qcfg, &opts.schedule)?
    };
    Ok((qm, t0.elapsed().as_secs_f64(), reports))
}

/// Quantize, save to `.stz`, return the path's byte size.
pub fn run_and_save(
    mw: &ModelWeights,
    qcfg: &QuantConfig,
    opts: &PipelineOpts,
    out_path: impl AsRef<Path>,
) -> anyhow::Result<(QuantizedModel, usize)> {
    let (qm, _) = run(mw, qcfg, opts)?;
    qm.save(&out_path)?;
    let bytes = std::fs::metadata(&out_path)?.len() as usize;
    Ok((qm, bytes))
}

/// Quantize `mw` and wire the result straight into a [`NativeBackend`] —
/// no `.stz` round-trip, no artifacts. This is the serving path for boxes
/// without XLA: the packed codes produced by the scheduler become the
/// backend's resident weight format directly. `engine` carries the decode
/// defaults the backend hands to every decoder it builds: KV precision,
/// concurrency cap, context cap, and page-pool geometry.
pub fn run_to_backend(
    mw: &ModelWeights,
    qcfg: &QuantConfig,
    opts: &PipelineOpts,
    engine: EngineConfig,
) -> anyhow::Result<NativeBackend> {
    let (qm, _, reports) = run_traced(mw, qcfg, opts)?;
    let report = crate::obs::QuantReport::new(&qm.method, qm.bits, reports);
    Ok(NativeBackend::from_quantized(&qm)
        .with_engine(engine)
        .with_quant_report(Some(report)))
}

/// PJRT-accelerated Algorithm 1: run the lowered Pallas `sinq_quantize`
/// artifact for a layer shape. Returns (codes, scales, shifts, t) — the
/// same contract as `quant::sinq::quantize` (modulo the ragged-group cases
/// the artifact does not cover).
pub fn sinq_quantize_pjrt(
    rt: &PjrtRuntime,
    w: &Matrix,
) -> anyhow::Result<QuantizedLinear> {
    let artifact = format!("sinq_quantize_{}x{}.hlo.txt", w.rows, w.cols);
    let exe = rt.load(&artifact)?;
    let arg = client::lit_matrix(w)?;
    let result = exe.execute(&[arg]).map_err(|e| anyhow::anyhow!("execute {artifact}: {e}"))?;
    let lit = result[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("{e}"))?;
    let (codes_l, scales_l, shifts_l, t_l) =
        lit.to_tuple4().map_err(|e| anyhow::anyhow!("tuple4: {e}"))?;
    let codes_i32 = codes_l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    let group = 64usize;
    let n_groups = w.cols / group;
    Ok(QuantizedLinear {
        rows: w.rows,
        cols: w.cols,
        group_size: group,
        grid: crate::fmt::grids::Grid::uniform(4),
        codes: codes_i32.iter().map(|&c| c as u8).collect(),
        scales: Matrix::from_vec(w.rows, n_groups, client::literal_to_f32(&scales_l)?),
        shifts: Some(Matrix::from_vec(w.rows, n_groups, client::literal_to_f32(&shifts_l)?)),
        col_scale: Some(client::literal_to_f32(&t_l)?),
        hadamard: false,
        hadamard_out: false,
        pair_codebook: None,
        aux: crate::quant::AuxPrecision::F32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::load_or_synthetic;
    use crate::quant::{Method, QuantConfig};

    #[test]
    fn pipeline_round_trip_via_disk() {
        let mw = load_or_synthetic("/nonexistent", "pico", 71);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let path = std::env::temp_dir().join("sinq_pipeline_test.stz");
        let (qm, bytes) =
            run_and_save(&mw, &cfg, &PipelineOpts::default(), &path).unwrap();
        assert!(bytes > 1000);
        let back = QuantizedModel::load(&path).unwrap();
        assert_eq!(back.layers.len(), qm.layers.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pipeline_feeds_native_backend() {
        let mw = load_or_synthetic("/nonexistent", "pico", 73);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let engine = EngineConfig::new().with_max_batch(8);
        let be = run_to_backend(&mw, &cfg, &PipelineOpts::default(), engine).unwrap();
        assert!(be.quantized_layer_count() > 0);
        let logits = be.forward(b"pipeline to backend").unwrap();
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // The quantize-and-serve path carries the build-time quality report.
        let report = be.quant_report().expect("quant report attached");
        assert_eq!(report.layers.len(), mw.cfg.quantizable_names().len());
        assert!(report.mean_nmse() > 0.0);
        assert!(report.layers.iter().all(|l| l.sinkhorn_iters.is_some()));
    }

    #[test]
    fn no_overhead_pipeline_produces_single_scale() {
        let mw = load_or_synthetic("/nonexistent", "pico", 72);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let opts = PipelineOpts { no_overhead: true, ..Default::default() };
        let (qm, _) = run(&mw, &cfg, &opts).unwrap();
        assert!(qm.method.contains("no-overhead"));
        assert!(qm.layers.values().all(|q| q.col_scale.is_none()));
    }
}
