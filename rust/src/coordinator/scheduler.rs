//! Per-layer quantization job scheduling.
//!
//! Quantizing a model is embarrassingly parallel across layers *after* a
//! sequential activation-capture pre-pass (calibrated methods need layer
//! inputs). The scheduler runs the pre-pass once, then fans layer jobs out
//! over scoped worker threads, preserving deterministic output order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::model::forward::{Capture, Forward};
use crate::model::{ModelConfig, ModelWeights, QuantizedModel};
use crate::quant::{quantize_matrix_traced, Calibration, QuantConfig, QuantizedLinear};
use crate::util::threadpool;

/// Progress/outcome of one scheduled job. Now the full per-layer
/// quantization-quality record (timing, memory, reconstruction error,
/// Sinkhorn convergence) consumed by the build-time [`crate::obs::QuantReport`].
pub type JobReport = crate::obs::LayerQuantStats;

/// Scheduler options.
#[derive(Debug, Clone)]
pub struct ScheduleOpts {
    pub threads: usize,
    /// Calibration sample (token bytes) for activation capture; required by
    /// calibrated methods.
    pub calib_sample: Option<Vec<u8>>,
    pub verbose: bool,
}

impl Default for ScheduleOpts {
    fn default() -> Self {
        ScheduleOpts { threads: 2, calib_sample: None, verbose: false }
    }
}

/// Run the capture pre-pass (when needed) and quantize every quantizable
/// layer of `mw` under `cfg`. Returns the quantized model + per-job reports.
pub fn quantize_model(
    mw: &ModelWeights,
    cfg: &QuantConfig,
    opts: &ScheduleOpts,
) -> anyhow::Result<(QuantizedModel, Vec<JobReport>)> {
    let names = mw.cfg.quantizable_names();

    // Pre-pass: capture per-layer inputs if the method needs calibration.
    let calib: BTreeMap<String, Calibration> = if cfg.method.needs_calibration() {
        let sample = opts
            .calib_sample
            .clone()
            .ok_or_else(|| anyhow::anyhow!("method {} needs --calib data", cfg.method.name()))?;
        capture_calibration(mw, &sample, &names)?
    } else {
        BTreeMap::new()
    };

    let done = AtomicUsize::new(0);
    let results: Vec<anyhow::Result<(QuantizedLinear, JobReport)>> =
        threadpool::map_indexed(&names, opts.threads, |_, name| {
            let t0 = Instant::now();
            let w = &mw.tensors[name];
            let (q, scales) = quantize_matrix_traced(w, cfg, calib.get(name))?;
            // Reconstruction error of the layer the decoder will actually
            // run: NMSE = ‖W−Ŵ‖²_F/‖W‖²_F, MSE = ‖W−Ŵ‖²_F/numel.
            let nmse = crate::quant::metrics::rel_fro(w, &q.effective_weight()).powi(2);
            let w_fro2: f64 = w.data.iter().map(|&x| (x as f64).powi(2)).sum();
            let mse = nmse * w_fro2 / (w.rows * w.cols).max(1) as f64;
            let report = JobReport {
                layer: name.clone(),
                millis: t0.elapsed().as_secs_f64() * 1e3,
                bits_per_weight: q.bits_per_weight(),
                rows: w.rows,
                cols: w.cols,
                mse,
                nmse,
                sinkhorn_iters: scales.as_ref().map(|s| s.iters),
                imbalance_initial: scales.as_ref().map(|s| s.initial_imbalance),
                imbalance_final: scales.as_ref().map(|s| s.imbalance),
            };
            let n = done.fetch_add(1, Ordering::SeqCst) + 1;
            if opts.verbose {
                println!("  [{n}/{}] {name} ({:.1} ms)", names.len(), report.millis);
            }
            Ok((q, report))
        });

    let mut layers = BTreeMap::new();
    let mut reports = Vec::new();
    for (name, r) in names.iter().zip(results) {
        let (q, rep) = r.map_err(|e| anyhow::anyhow!("layer {name}: {e}"))?;
        layers.insert(name.clone(), q);
        reports.push(rep);
    }

    let qnames = mw.cfg.quantizable_names();
    let fweights: BTreeMap<String, _> = mw
        .tensors
        .iter()
        .filter(|(k, _)| !qnames.contains(k))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    Ok((
        QuantizedModel {
            cfg: mw.cfg.clone(),
            layers,
            fweights,
            fvectors: mw.vectors.clone(),
            method: cfg.method.name().to_string(),
            bits: cfg.bits,
        },
        reports,
    ))
}

/// One forward pass over the calibration sample, recording every linear's
/// inputs; returns per-layer [`Calibration`].
pub fn capture_calibration(
    mw: &ModelWeights,
    sample: &[u8],
    names: &[String],
) -> anyhow::Result<BTreeMap<String, Calibration>> {
    let mut cap = Capture::new(64);
    let fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    for w in sample.chunks(128).take(6) {
        let _ = fwd.forward(w, Some(&mut cap));
    }
    let mut out = BTreeMap::new();
    for name in names {
        let x = cap
            .calibration(name)
            .ok_or_else(|| anyhow::anyhow!("no activations captured for {name}"))?;
        out.insert(name.clone(), Calibration::from_activations(x));
    }
    Ok(out)
}

/// Convenience used throughout benches/tables: quantize with defaults.
pub fn quantize_simple(
    mw: &ModelWeights,
    cfg: &QuantConfig,
    calib_sample: Option<&[u8]>,
) -> anyhow::Result<QuantizedModel> {
    let opts = ScheduleOpts {
        threads: 2,
        calib_sample: calib_sample.map(|s| s.to_vec()),
        verbose: false,
    };
    Ok(quantize_model(mw, cfg, &opts)?.0)
}

/// Which models the experiment tables sweep, resolved against artifacts.
pub fn load_family_member(art_dir: &str, name: &str) -> anyhow::Result<ModelWeights> {
    ModelWeights::load(format!("{art_dir}/models/{name}.stz"))
}

/// Fallback for tests: synthetic when artifacts are absent.
pub fn load_or_synthetic(art_dir: &str, name: &str, seed: u64) -> ModelWeights {
    load_family_member(art_dir, name).unwrap_or_else(|_| {
        ModelWeights::synthetic(&ModelConfig::family(name).expect("family model"), seed)
    })
}

/// [`load_or_synthetic`] for runtime paths: synthesizes only when the
/// checkpoint file is genuinely absent (a corrupt or unreadable `.stz` is a
/// real error and propagates), errors on an unknown family, and prints a
/// notice when falling back — so `serve`/`eval` on the native backend stay
/// usable on artifact-free machines without masking broken artifacts.
pub fn load_or_synthetic_checked(
    art_dir: &str,
    name: &str,
    seed: u64,
) -> anyhow::Result<ModelWeights> {
    if std::path::Path::new(&format!("{art_dir}/models/{name}.stz")).exists() {
        return load_family_member(art_dir, name);
    }
    let cfg = ModelConfig::family(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    eprintln!(
        "note: no checkpoint for '{name}' under {art_dir}/models — \
         using a synthetic model"
    );
    Ok(ModelWeights::synthetic(&cfg, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;

    #[test]
    fn schedules_all_layers_uncalibrated() {
        let mw = load_or_synthetic("/nonexistent", "pico", 61);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let (qm, reports) = quantize_model(&mw, &cfg, &ScheduleOpts::default()).unwrap();
        assert_eq!(qm.layers.len(), mw.cfg.quantizable_names().len());
        assert_eq!(reports.len(), qm.layers.len());
        assert!(reports.iter().all(|r| r.bits_per_weight > 4.0));
        assert!(qm.fweights.contains_key("embed"));
        // SINQ layers carry the full quality record: finite positive error,
        // Sinkhorn convergence info, and an imbalance that did not worsen.
        for r in &reports {
            assert!(r.nmse > 0.0 && r.nmse < 1.0, "{}: nmse {}", r.layer, r.nmse);
            assert!(r.mse > 0.0, "{}: mse {}", r.layer, r.mse);
            assert!(r.rows > 0 && r.cols > 0);
            assert!(r.sinkhorn_iters.is_some(), "{}: no sinkhorn iters", r.layer);
            let (i0, i1) = (r.imbalance_initial.unwrap(), r.imbalance_final.unwrap());
            assert!(i1 <= i0, "{}: imbalance {} -> {}", r.layer, i0, i1);
        }
    }

    #[test]
    fn calibrated_method_without_sample_errors() {
        let mw = load_or_synthetic("/nonexistent", "pico", 62);
        let cfg = QuantConfig::new(Method::Awq, 4);
        assert!(quantize_model(&mw, &cfg, &ScheduleOpts::default()).is_err());
    }

    #[test]
    fn calibrated_method_with_sample_succeeds() {
        let mw = load_or_synthetic("/nonexistent", "pico", 63);
        let cfg = QuantConfig::new(Method::Awq, 4);
        let opts = ScheduleOpts {
            calib_sample: Some(b"calibration text sample ".repeat(30).to_vec()),
            ..Default::default()
        };
        let (qm, _) = quantize_model(&mw, &cfg, &opts).unwrap();
        assert!(qm.layers.values().all(|q| q.col_scale.is_some()));
    }

    #[test]
    fn parallel_matches_serial() {
        let mw = load_or_synthetic("/nonexistent", "pico", 64);
        let cfg = QuantConfig::new(Method::Sinq, 4);
        let (a, _) =
            quantize_model(&mw, &cfg, &ScheduleOpts { threads: 1, ..Default::default() }).unwrap();
        let (b, _) =
            quantize_model(&mw, &cfg, &ScheduleOpts { threads: 4, ..Default::default() }).unwrap();
        for (name, qa) in &a.layers {
            assert_eq!(qa.codes, b.layers[name].codes, "{name}");
        }
    }
}
