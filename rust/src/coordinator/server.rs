//! Serving coordinator: request router + dynamic batcher + generation queue.
//!
//! Scoring requests (perplexity windows, QA option scoring) arrive on a
//! channel; the batcher groups up to `backend.max_batch()` compatible
//! requests within a `max_wait` window and dispatches one backend execution
//! per batch — the same shape as a vLLM-style router scaled to one box.
//! Generation requests ride the same channel and drain into
//! [`InferenceBackend::generate_batch`]: on the native backend that is the
//! continuous-batching `BatchDecoder`, which admits the queued requests
//! into KV slots and recycles slots as sequences finish, so one dispatched
//! group can hold more requests than the backend has slots. The server is
//! generic over [`InferenceBackend`], so the same loop drives the PJRT
//! artifact executor *and* the native fused-kernel engine (which needs no
//! artifacts at all). Backpressure is a bounded queue: submitters block
//! when the queue is full.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread;
use std::time::{Duration, Instant};

use crate::backend::InferenceBackend;
use crate::tensor::Matrix;

/// One scoring request: a token sequence, answered with per-position logits.
pub struct ScoreRequest {
    pub tokens: Vec<u8>,
    pub reply: SyncSender<anyhow::Result<Matrix>>,
}

/// One generation request: a prompt plus a token budget, answered with the
/// greedily generated continuation.
pub struct GenerateRequest {
    pub prompt: Vec<u8>,
    pub max_new: usize,
    pub reply: SyncSender<anyhow::Result<Vec<u8>>>,
}

/// Channel item: a request or an explicit shutdown (outstanding
/// [`ScoreClient`] clones keep the channel open, so closure alone cannot
/// signal termination).
enum Msg {
    Score(ScoreRequest),
    Generate(GenerateRequest),
    Shutdown,
}

/// Server statistics (throughput accounting for Table 6-style reporting).
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub tokens: usize,
    /// Generation requests served.
    pub gen_requests: usize,
    /// Generation groups dispatched to the backend.
    pub gen_batches: usize,
    /// Tokens generated across all generation requests.
    pub generated: usize,
}

/// The batching server: owns the inference backend on a worker thread.
pub struct BatchServer {
    tx: Option<SyncSender<Msg>>,
    handle: Option<thread::JoinHandle<ServerStats>>,
}

impl BatchServer {
    /// Spawn with a bounded queue (`queue_cap`) and batching window.
    ///
    /// Some backends hold handles that are not `Send` (PJRT), so the
    /// backend is *constructed on the server thread* from the given builder
    /// (which captures only plain data: artifact paths, configs, weights).
    pub fn spawn<B, F>(builder: F, queue_cap: usize, max_wait: Duration) -> BatchServer
    where
        B: InferenceBackend + 'static,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Msg>(queue_cap);
        let handle = thread::Builder::new()
            .name("sinq-batch-server".into())
            .spawn(move || match builder() {
                Ok(backend) => serve_loop(backend, rx, max_wait),
                Err(e) => {
                    // Fail every request with the build error.
                    let msg = format!("server init failed: {e}");
                    while let Ok(m) = rx.recv() {
                        match m {
                            Msg::Score(req) => {
                                let _ = req.reply.send(Err(anyhow::anyhow!("{msg}")));
                            }
                            Msg::Generate(req) => {
                                let _ = req.reply.send(Err(anyhow::anyhow!("{msg}")));
                            }
                            Msg::Shutdown => break,
                        }
                    }
                    ServerStats::default()
                }
            })
            .expect("spawn server");
        BatchServer { tx: Some(tx), handle: Some(handle) }
    }

    /// Client handle for submitting requests.
    pub fn client(&self) -> ScoreClient {
        ScoreClient { tx: self.tx.as_ref().expect("server alive").clone() }
    }

    /// Shut down and return stats. Outstanding clients get errors on
    /// further submissions once the worker drains.
    pub fn shutdown(mut self) -> ServerStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        self.handle.take().unwrap().join().unwrap_or_default()
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.try_send(Msg::Shutdown);
        }
        // Intentionally no join here: avoids blocking panic paths.
    }
}

/// Cheap cloneable submitter.
#[derive(Clone)]
pub struct ScoreClient {
    tx: SyncSender<Msg>,
}

impl ScoreClient {
    /// Blocking request → logits.
    pub fn score(&self, tokens: Vec<u8>) -> anyhow::Result<Matrix> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Msg::Score(ScoreRequest { tokens, reply }))
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Non-blocking submit (backpressure probe); Err(tokens) when full.
    pub fn try_submit(
        &self,
        tokens: Vec<u8>,
    ) -> Result<Receiver<anyhow::Result<Matrix>>, Vec<u8>> {
        let (reply, rx) = sync_channel(1);
        match self.tx.try_send(Msg::Score(ScoreRequest { tokens, reply })) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(Msg::Score(req)))
            | Err(TrySendError::Disconnected(Msg::Score(req))) => Err(req.tokens),
            Err(_) => Err(Vec::new()),
        }
    }

    /// Blocking generation request → greedy continuation of `max_new`
    /// tokens. Concurrent callers are grouped into one continuous-batching
    /// dispatch on the server thread.
    pub fn generate(&self, prompt: Vec<u8>, max_new: usize) -> anyhow::Result<Vec<u8>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Msg::Generate(GenerateRequest { prompt, max_new, reply }))
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }
}

fn serve_loop<B: InferenceBackend>(
    mut backend: B,
    rx: Receiver<Msg>,
    max_wait: Duration,
) -> ServerStats {
    let batch_cap = backend.max_batch().max(1);
    // Generation groups admit up to 2× the backend's slot count: the
    // continuous-batching decoder refills freed slots from its pending
    // queue mid-run, so oversubscription raises utilization rather than
    // latency.
    let gen_cap = 2 * batch_cap;
    let mut stats = ServerStats::default();
    let mut shutdown = false;
    loop {
        // Block for the first request of a batch.
        let mut scores: Vec<ScoreRequest> = Vec::new();
        let mut gens: Vec<GenerateRequest> = Vec::new();
        match rx.recv() {
            Ok(Msg::Score(r)) => scores.push(r),
            Ok(Msg::Generate(r)) => gens.push(r),
            Ok(Msg::Shutdown) | Err(_) => return stats,
        }
        // Admit more work of either kind within the batching window.
        let deadline = Instant::now() + max_wait;
        while scores.len() < batch_cap && gens.len() < gen_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Score(r)) => scores.push(r),
                Ok(Msg::Generate(r)) => gens.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }

        if !scores.is_empty() {
            let batch = scores;
            let seqs: Vec<&[u8]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
            stats.requests += batch.len();
            stats.batches += 1;
            stats.tokens += seqs.iter().map(|s| s.len()).sum::<usize>();
            match backend.forward_batch(&seqs) {
                Ok(results) => {
                    for (req, m) in batch.into_iter().zip(results) {
                        let _ = req.reply.send(Ok(m));
                    }
                }
                Err(e) => {
                    let msg = format!("{e}");
                    for req in batch {
                        let _ = req.reply.send(Err(anyhow::anyhow!("{msg}")));
                    }
                }
            }
        }

        if !gens.is_empty() {
            let batch = gens;
            let prompts: Vec<&[u8]> = batch.iter().map(|r| r.prompt.as_slice()).collect();
            let max_new: Vec<usize> = batch.iter().map(|r| r.max_new).collect();
            stats.gen_requests += batch.len();
            stats.gen_batches += 1;
            match backend.generate_batch(&prompts, &max_new) {
                Ok(outs) => {
                    for (req, toks) in batch.into_iter().zip(outs) {
                        stats.generated += toks.len();
                        let _ = req.reply.send(Ok(toks));
                    }
                }
                Err(_) => {
                    // A grouped failure (e.g. one invalid request) must not
                    // poison the whole window: retry each request alone so
                    // only the genuinely bad ones fail.
                    for req in batch {
                        let result = backend.generate(&req.prompt, req.max_new);
                        if let Ok(toks) = &result {
                            stats.generated += toks.len();
                        }
                        let _ = req.reply.send(result);
                    }
                }
            }
        }

        if shutdown {
            return stats;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::LogitsEngine;

    /// Deterministic toy backend: logit row p puts mass on token p (mod 256).
    struct Echo {
        calls: usize,
    }

    impl LogitsEngine for Echo {
        fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
            self.calls += 1;
            let mut m = Matrix::zeros(tokens.len(), 256);
            for p in 0..tokens.len() {
                *m.at_mut(p, p % 256) = 1.0;
            }
            Ok(m)
        }
    }

    impl InferenceBackend for Echo {
        fn name(&self) -> &'static str {
            "echo"
        }

        fn max_batch(&self) -> usize {
            4
        }

        fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            Ok(vec![prompt.len() as u8; n])
        }
    }

    #[test]
    fn stats_default_zero() {
        let s = ServerStats::default();
        assert_eq!((s.requests, s.batches, s.tokens), (0, 0, 0));
    }

    #[test]
    fn batches_and_answers_requests() {
        let server =
            BatchServer::spawn(|| Ok(Echo { calls: 0 }), 16, Duration::from_millis(2));
        let client = server.client();
        let handles: Vec<_> = (0..10)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.score(vec![i as u8; 8]))
            })
            .collect();
        for h in handles {
            let m = h.join().unwrap().unwrap();
            assert_eq!((m.rows, m.cols), (8, 256));
            assert_eq!(m.at(3, 3), 1.0);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 10);
        assert_eq!(stats.tokens, 80);
        assert!(stats.batches >= 3, "4-way cap ⇒ ≥3 batches, got {}", stats.batches);
    }

    #[test]
    fn generation_queue_groups_and_answers() {
        let server =
            BatchServer::spawn(|| Ok(Echo { calls: 0 }), 16, Duration::from_millis(2));
        let client = server.client();
        let handles: Vec<_> = (1..=6usize)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.generate(vec![0u8; i], 4 + i))
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap().unwrap();
            assert_eq!(out, vec![(i + 1) as u8; 5 + i]);
        }
        let stats = server.shutdown();
        assert_eq!(stats.gen_requests, 6);
        assert_eq!(stats.generated, (5..=10).sum::<usize>());
        assert!(stats.gen_batches >= 1);
    }

    #[test]
    fn invalid_generation_request_does_not_poison_group() {
        let server =
            BatchServer::spawn(|| Ok(Echo { calls: 0 }), 16, Duration::from_millis(5));
        let client = server.client();
        let bad = {
            let c = client.clone();
            std::thread::spawn(move || c.generate(Vec::new(), 3))
        };
        let good = {
            let c = client.clone();
            std::thread::spawn(move || c.generate(vec![9u8; 2], 3))
        };
        assert!(bad.join().unwrap().is_err(), "empty prompt must fail");
        assert_eq!(good.join().unwrap().unwrap(), vec![2u8; 3]);
        server.shutdown();
    }

    #[test]
    fn mixed_scoring_and_generation_both_answered() {
        let server =
            BatchServer::spawn(|| Ok(Echo { calls: 0 }), 16, Duration::from_millis(2));
        let client = server.client();
        let g = {
            let c = client.clone();
            std::thread::spawn(move || c.generate(vec![7u8; 3], 2))
        };
        let s = {
            let c = client.clone();
            std::thread::spawn(move || c.score(vec![1u8; 8]))
        };
        assert_eq!(g.join().unwrap().unwrap(), vec![3u8, 3]);
        assert_eq!(s.join().unwrap().unwrap().rows, 8);
        let stats = server.shutdown();
        assert_eq!((stats.requests, stats.gen_requests), (1, 1));
    }

    #[test]
    fn failed_builder_errors_requests() {
        let server = BatchServer::spawn::<Echo, _>(
            || Err(anyhow::anyhow!("no model")),
            4,
            Duration::from_millis(1),
        );
        let client = server.client();
        let err = client.score(vec![1, 2, 3]).unwrap_err();
        assert!(err.to_string().contains("server init failed"), "{err}");
        server.shutdown();
    }
}
