//! Serving front-end throughput through the real HTTP/SSE endpoint:
//! requests/sec and median time-to-first-token at client concurrency
//! 1/4/16 against a `sinq::serve::Server` bound to 127.0.0.1:0 (tiny
//! model, SINQ 4-bit, no artifacts needed). Unlike `benches/decode.rs`,
//! which times the decoder in-process, this path pays the full protocol
//! stack: TCP accept, HTTP parse, admission control, per-token SSE writes.
//!
//! A summary lands in `BENCH_serve.json` at the repository root (validated
//! by `scripts/check_bench.sh` in CI). Run with `cargo bench --bench
//! serve`; set `BENCH_QUICK=1` (or pass `--quick`) for the
//! reduced-iteration CI smoke mode.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use sinq::backend::{BackendKind, BackendSpec};
use sinq::quant::{Method, QuantConfig};
use sinq::serve::{ServeOpts, Server};
use sinq::util::json::Json;

/// One streamed generation over a raw TcpStream; returns (ttft, total)
/// wall-clock durations measured from the request write.
fn streamed_request(addr: &str, prompt: &str, max_new: usize) -> (f64, f64) {
    let body = Json::obj(vec![
        ("prompt", Json::Str(prompt.into())),
        ("max_new_tokens", Json::Num(max_new as f64)),
        ("stream", Json::Bool(true)),
    ])
    .to_string_compact();
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().expect("clone");
    let t0 = Instant::now();
    write!(
        w,
        "POST /v1/generate HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    assert!(line.starts_with("HTTP/1.1 200"), "unexpected response: {line}");
    let mut ttft = None;
    let mut done = false;
    while !done {
        line.clear();
        if reader.read_line(&mut line).expect("read event") == 0 {
            break;
        }
        if line.starts_with("event: token") && ttft.is_none() {
            ttft = Some(t0.elapsed().as_secs_f64());
        } else if line.starts_with("event: done") || line.starts_with("event: error") {
            done = true;
        }
    }
    assert!(done, "stream ended without a terminal event");
    (ttft.expect("no token event"), t0.elapsed().as_secs_f64())
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if xs.is_empty() {
        return 0.0;
    }
    xs[xs.len() / 2]
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok() || std::env::args().any(|a| a == "--quick");
    let (per_client, max_new) = if quick { (2usize, 8usize) } else { (6, 24) };

    let mut spec = BackendSpec::new(BackendKind::Native, "artifacts", "tiny");
    spec.quantize = Some(QuantConfig::new(Method::Sinq, 4));
    spec.engine = spec.engine.with_max_batch(8);
    let opts = ServeOpts {
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        // Room for the shared-prefix phase: 512-token prefix + suffix +
        // generation.
        max_context: 640,
        max_queue: 256,
        default_max_new: max_new,
        ..ServeOpts::default()
    };
    let server = Server::start(&spec, &opts).expect("server start");
    let addr = server.addr.to_string();
    println!("serve bench: tiny/sinq-4b on {addr}, +{max_new} tokens per request\n");

    let mut summary: Vec<Json> = Vec::new();
    for conc in [1usize, 4, 16] {
        let n_requests = conc * per_client;
        let ttfts = Arc::new(Mutex::new(Vec::<f64>::new()));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..conc)
            .map(|c| {
                let addr = addr.clone();
                let ttfts = ttfts.clone();
                std::thread::spawn(move || {
                    for r in 0..per_client {
                        let prompt = format!("client {c} request {r} says hello");
                        let (ttft, _total) = streamed_request(&addr, &prompt, max_new);
                        ttfts.lock().unwrap().push(ttft);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }
        let secs = t0.elapsed().as_secs_f64();
        let mut ttfts = ttfts.lock().unwrap().clone();
        let rps = n_requests as f64 / secs;
        let ttft_ms = median(&mut ttfts) * 1e3;
        println!(
            "concurrency {conc:>2}: {n_requests} requests in {secs:.3}s \
             → {rps:.1} req/s, median TTFT {ttft_ms:.1} ms"
        );
        summary.push(Json::obj(vec![
            ("batch", Json::Num(conc as f64)),
            ("requests", Json::Num(n_requests as f64)),
            ("secs", Json::Num(secs)),
            ("requests_per_sec", Json::Num(rps)),
            ("ttft_median_ms", Json::Num(ttft_ms)),
        ]));
    }

    // Shared-prefix TTFT: one cold decode of a 512-token prompt seeds the
    // prefix cache, then 16 concurrent clients share that prefix (distinct
    // suffixes) and should see far lower time-to-first-token because the
    // cached pages skip prefill for the shared span.
    let prefix: String =
        "sinkhorn normalized quantization ".chars().cycle().take(512).collect();
    let (ttft_cold, _) = streamed_request(&addr, &prefix, max_new);
    let hit_ttfts = Arc::new(Mutex::new(Vec::<f64>::new()));
    let handles: Vec<_> = (0..16usize)
        .map(|c| {
            let addr = addr.clone();
            let prompt = format!("{prefix}client {c:02}");
            let hit_ttfts = hit_ttfts.clone();
            std::thread::spawn(move || {
                let (ttft, _total) = streamed_request(&addr, &prompt, max_new);
                hit_ttfts.lock().unwrap().push(ttft);
            })
        })
        .collect();
    for h in handles {
        h.join().expect("prefix client thread");
    }
    let mut hit_ttfts = hit_ttfts.lock().unwrap().clone();
    let ttft_cold_prefix_ms = ttft_cold * 1e3;
    let ttft_hit_prefix_ms = median(&mut hit_ttfts) * 1e3;
    println!(
        "\nshared prefix ({} tokens, concurrency 16): cold TTFT \
         {ttft_cold_prefix_ms:.1} ms, median hit TTFT {ttft_hit_prefix_ms:.1} ms",
        prefix.len()
    );

    let stats = server.shutdown();
    println!(
        "\nserved {} requests, {} tokens total",
        stats.gen_requests, stats.gen_tokens
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("model", Json::Str("tiny".to_string())),
        ("method", Json::Str("sinq".to_string())),
        ("bits", Json::Num(4.0)),
        ("max_new_tokens", Json::Num(max_new as f64)),
        ("quick", Json::Bool(quick)),
        ("prefix_tokens", Json::Num(prefix.len() as f64)),
        ("ttft_cold_prefix_ms", Json::Num(ttft_cold_prefix_ms)),
        ("ttft_hit_prefix_ms", Json::Num(ttft_hit_prefix_ms)),
        ("results", Json::Arr(summary)),
    ]);
    // Repo root, resolved from the package dir so cwd does not matter.
    let out = format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&out, report.to_string_compact()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
