//! Table 5 — marginal cost of the second scale `t` on the fused
//! dequant-matmul kernel, measured through the PJRT-compiled Pallas
//! artifacts (`dqmm_b{B}_d{D}[_dual].hlo.txt`).
//!
//! `cargo bench --bench kernel_overhead` (requires `make artifacts`)

use sinq::backend::BackendKind;
use sinq::report::tables::{table5, Ctx};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // fast mode: 5 timed iterations per variant (full run: `sinq table 5`)
    let ctx = Ctx::with_backend("artifacts", true, BackendKind::Pjrt).expect("PJRT runtime");
    let t = table5(&ctx).expect("table 5");
    t.print();
    let _ = t.dump("artifacts");
}
