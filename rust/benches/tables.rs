//! End-to-end regeneration of the paper's perplexity/flip tables in fast
//! mode — one bench per table, as the benchmark deliverable requires. The
//! full-resolution numbers recorded in EXPERIMENTS.md come from
//! `sinq table all` (same code, larger sweeps).
//!
//! `cargo bench --bench tables` (requires `make artifacts`)

use sinq::report::tables::{self, Ctx};
use std::time::Instant;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ctx = Ctx::new("artifacts", true).expect("PJRT runtime");
    let models = ["pico", "tiny"];

    let mut timed = |name: &str, f: &dyn Fn() -> anyhow::Result<sinq::report::Table>| {
        let t0 = Instant::now();
        match f() {
            Ok(table) => {
                table.print();
                let _ = table.dump("artifacts");
                println!("[bench] {name} regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[bench] {name} FAILED: {e}"),
        }
    };

    timed("table1", &|| tables::table1(&ctx, &models));
    timed("table3", &|| tables::table3(&ctx, &["tiny"]));
    timed("table4", &|| tables::table4(&ctx, &["tiny"]));
    timed("table7", &|| tables::table7(&ctx, "tiny"));
    timed("table8", &|| tables::table8(&ctx, &["tiny"]));
    timed("table9", &|| tables::table9(&ctx, &["tiny"]));
    timed("table16", &|| tables::table16(&ctx, "pico"));
    timed("table17", &|| tables::table17(&ctx, "tiny"));
    timed("table18", &|| tables::table18(&ctx, &["tiny"]));
    timed("table19", &|| tables::table19(&ctx));
    timed("ablations (fig5)", &|| tables::ablation_table(&ctx, &["tiny"]));
    timed("fig1", &|| tables::fig1_table(&ctx));
    timed("fig2b", &|| tables::fig2b_table(&ctx));
    timed("fig2c/fig7", &|| tables::fig2c_fig7_table(&ctx, "tiny"));
    timed("fig3", &|| tables::fig3_table(&ctx, "tiny"));

    // Table 2 (flips) is the slowest sweep; opt in with BENCH_TABLE2=1
    // (full-resolution run: `sinq table 2`).
    if std::env::var("BENCH_TABLE2").is_ok() {
        let t0 = Instant::now();
        match tables::table2(&ctx, &["tiny"]) {
            Ok((flip_t, acc)) => {
                flip_t.print();
                acc.print();
                let _ = flip_t.dump("artifacts");
                let _ = acc.dump("artifacts");
                println!("[bench] table2/14 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("[bench] table2 FAILED: {e}"),
        }
    } else {
        println!("[bench] table2 skipped (set BENCH_TABLE2=1; full run: sinq table 2)");
    }
}
