//! Fused dequant-matmul vs dequantize-then-matmul across bit widths — the
//! native backend's reason to exist, measured — plus the scalar-vs-SIMD
//! delta of the dispatched decode kernels.
//!
//! For each `bits ∈ {2, 3, 4, 8}` on the tiny model's largest linear shape
//! (ffn×d = 512×128) this times:
//!
//! * `fused`    — `QuantizedTensor::dequant_matmul` (tile-wise unpack +
//!   multiply in one pass, codes stay packed);
//! * `baseline` — materialize the full f32 weight matrix (`to_dense`) then
//!   `matmul_nt`, i.e. what `model/forward.rs` over effective weights does;
//! * the same pair for the single-vector decode path (`dequant_matvec`);
//! * the decode kernels (`dequant_matvec`, 16-row
//!   `dequant_matmul_shared`) under the auto-dispatched SIMD kernel **and**
//!   the forced scalar fallback, with effective packed-payload GB/s for
//!   both, so the SIMD speedup lands in the perf trajectory.
//!
//! Results append to `artifacts/bench_backend.jsonl` (raw samples) and a
//! summary with fused-vs-baseline and scalar-vs-SIMD speedups is written
//! to `BENCH_backend.json` at the repository root.
//!
//! Run with `cargo bench --bench backend`; set `BENCH_QUICK=1` (or pass
//! `--quick`) for the reduced-iteration CI smoke mode.

use sinq::backend::simd::{self, Isa};
use sinq::backend::QuantizedTensor;
use sinq::quant::{quantize_matrix, Method, QuantConfig};
use sinq::tensor::{Matrix, Rng};
use sinq::util::bench::Bencher;
use sinq::util::json::Json;
use std::hint::black_box;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok() || std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(2025);

    simd::force(None);
    let kernel = simd::kernel_name().to_string();
    println!("dispatched simd kernel: '{kernel}'");

    // Tiny-model shapes: x is a 128-token window of d=128 activations; W is
    // the ffn→d projection (512×128), the model's largest linear.
    let (seq, d, ffn) = (128usize, 128usize, 512usize);
    let x = Matrix::randn(seq, d, 1.0, &mut rng);
    let xv = x.row(0).to_vec();
    // Decode-batch shape: 16 live sequences, one activation row each.
    let xb = Matrix::from_vec(16, d, x.data[..16 * d].to_vec());
    let w = Matrix::randn(ffn, d, 0.05, &mut rng);

    let mut summary: Vec<Json> = Vec::new();
    for bits in [2u32, 3, 4, 8] {
        let cfg = QuantConfig::new(Method::Sinq, bits);
        let q = quantize_matrix(&w, &cfg, None).expect("quantize");
        let qt = QuantizedTensor::from_linear(&q).expect("packable");

        // Sanity: fused and baseline agree before we time them.
        let dense = qt.to_dense();
        let y_fused = qt.dequant_matmul(&x, 1);
        let y_base = x.matmul_nt(&dense);
        let max_diff = y_fused
            .data
            .iter()
            .zip(&y_base.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "{bits}b fused/baseline disagree: {max_diff}");

        let fused = b.bench(&format!("dequant_matmul fused {bits}b 128x128·(512x128)ᵀ"), || {
            black_box(qt.dequant_matmul(&x, 1));
        });
        let base = b.bench(&format!("dequantize-then-matmul {bits}b"), || {
            let dense = qt.to_dense();
            black_box(x.matmul_nt(&dense));
        });
        let fused_mv = b.bench(&format!("dequant_matvec {kernel} {bits}b 512x128"), || {
            black_box(qt.dequant_matvec(&xv));
        });
        let base_mv = b.bench(&format!("dequantize-then-matvec {bits}b"), || {
            let dense = qt.to_dense();
            let xr = Matrix::from_vec(1, d, xv.clone());
            black_box(xr.matmul_nt(&dense));
        });
        // The continuous-batching decode kernel: one unpack per weight row
        // shared across 16 stacked sequences vs 16 independent matvecs.
        let shared16 =
            b.bench(&format!("dequant_matmul_shared {kernel} {bits}b 16x128·(512x128)ᵀ"), || {
                black_box(qt.dequant_matmul_shared(&xb, 1));
            });
        let mv16 = b.bench(&format!("16× dequant_matvec {bits}b"), || {
            for r in 0..16 {
                black_box(qt.dequant_matvec(xb.row(r)));
            }
        });

        // Scalar-vs-SIMD on the decode kernels: force the portable
        // fallback, re-time the same calls, restore auto dispatch.
        simd::force(Some(Isa::Scalar));
        let mv_scalar = b.bench(&format!("dequant_matvec scalar {bits}b 512x128"), || {
            black_box(qt.dequant_matvec(&xv));
        });
        let shared16_scalar =
            b.bench(&format!("dequant_matmul_shared scalar {bits}b 16x128·(512x128)ᵀ"), || {
                black_box(qt.dequant_matmul_shared(&xb, 1));
            });
        simd::force(None);

        // Effective packed-payload bandwidth: every matvec / shared step
        // streams the full packed code payload exactly once.
        let pb = qt.packed_bytes() as f64;
        let mv_gbps = pb / fused_mv.mean_ns;
        let mv_scalar_gbps = pb / mv_scalar.mean_ns;
        let mv_simd_speedup = mv_scalar.mean_ns / fused_mv.mean_ns;
        let shared_simd_speedup = shared16_scalar.mean_ns / shared16.mean_ns;

        let speedup = base.mean_ns / fused.mean_ns;
        let speedup_mv = base_mv.mean_ns / fused_mv.mean_ns;
        let speedup_shared = mv16.mean_ns / shared16.mean_ns;
        println!(
            "    -> {bits}b: matmul speedup {speedup:.2}x, matvec speedup {speedup_mv:.2}x, \
             shared-batch-16 speedup {speedup_shared:.2}x, packed {} KiB vs dense {} KiB",
            qt.packed_bytes() / 1024,
            (ffn * d * 4) / 1024,
        );
        println!(
            "       simd '{kernel}' vs scalar: matvec {mv_simd_speedup:.2}x \
             ({mv_gbps:.2} vs {mv_scalar_gbps:.2} packed GB/s), \
             shared-batch-16 {shared_simd_speedup:.2}x"
        );
        summary.push(Json::obj(vec![
            ("bits", Json::Num(bits as f64)),
            ("fused_matmul_ns", Json::Num(fused.mean_ns)),
            ("baseline_matmul_ns", Json::Num(base.mean_ns)),
            ("matmul_speedup", Json::Num(speedup)),
            ("fused_matvec_ns", Json::Num(fused_mv.mean_ns)),
            ("baseline_matvec_ns", Json::Num(base_mv.mean_ns)),
            ("matvec_speedup", Json::Num(speedup_mv)),
            ("shared_batch16_ns", Json::Num(shared16.mean_ns)),
            ("matvec16_ns", Json::Num(mv16.mean_ns)),
            ("shared_batch16_speedup", Json::Num(speedup_shared)),
            ("matvec_scalar_ns", Json::Num(mv_scalar.mean_ns)),
            ("matvec_simd_speedup", Json::Num(mv_simd_speedup)),
            ("matvec_gbps", Json::Num(mv_gbps)),
            ("matvec_scalar_gbps", Json::Num(mv_scalar_gbps)),
            ("shared_batch16_scalar_ns", Json::Num(shared16_scalar.mean_ns)),
            ("shared_batch16_simd_speedup", Json::Num(shared_simd_speedup)),
            ("packed_bytes", Json::Num(qt.packed_bytes() as f64)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("backend".to_string())),
        ("shape", Json::Str(format!("x({seq},{d}) · W({ffn},{d})ᵀ"))),
        ("method", Json::Str("sinq".to_string())),
        ("kernel", Json::Str(kernel)),
        ("results", Json::Arr(summary)),
    ]);
    // Repo root, resolved from the package dir so cwd does not matter.
    let out = format!("{}/../BENCH_backend.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&out, report.to_string_compact()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
    let _ = b.dump_jsonl("artifacts/bench_backend.jsonl");
}
