//! Table 6 — end-to-end decode throughput (W16A16 vs SINQ W4A16) through
//! the serving decoder with its on-device weights.
//!
//! `cargo bench --bench decode` (requires `make artifacts`)

use sinq::report::tables::{table6, Ctx};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // `fast` keeps the bench under a minute (64-token context, 64 generated);
    // the EXPERIMENTS.md numbers use the full 256/512 run via `sinq table 6`.
    let ctx = Ctx::new("artifacts", true).expect("PJRT runtime");
    let t = table6(&ctx, &["tiny", "small"]).expect("table 6");
    t.print();
    let _ = t.dump("artifacts");
}
