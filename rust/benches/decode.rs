//! Continuous-batched native decode throughput: aggregate tokens/sec at
//! batch sizes 1/4/16 on the tiny model (SINQ 4-bit), no artifacts needed —
//! measured under the runtime-dispatched SIMD kernels, the forced scalar
//! fallback, and the 8-bit quantized KV cache, so `BENCH_decode.json`
//! records the SIMD speedup and the kv-bits 32-vs-8 throughput (plus the
//! per-slot KV bytes both precisions occupy) alongside the batching
//! speedup.
//!
//! Batch 1 runs the single-sequence `NativeDecoder` (fused matvec path);
//! larger batches run the continuous-batching `BatchDecoder`, whose fused
//! stacked-row matmuls unpack every weight tile once per step and share it
//! across all live sequences. Before timing, batched tokens are asserted
//! bit-identical to single-sequence decode. A summary lands in
//! `BENCH_decode.json` at the repository root (the CI bench-smoke job
//! validates and archives it, including the scalar-vs-SIMD fields).
//!
//! Run with `cargo bench --bench decode`; set `BENCH_QUICK=1` (or pass
//! `--quick`) for the reduced-iteration CI smoke mode.

use std::sync::Arc;
use std::time::Instant;

use sinq::backend::simd::{self, Isa};
use sinq::backend::{BatchDecoder, EngineConfig, KvBits, NativeBackend, NativeDecoder};
use sinq::coordinator::scheduler::{load_or_synthetic, quantize_simple};
use sinq::data::Corpus;
use sinq::obs::{drift, journal, profiler};
use sinq::quant::{Method, QuantConfig};
use sinq::serve::engine::{GenEngine, StreamEvent};
use sinq::serve::metrics::ServeMetrics;
use sinq::util::json::Json;

/// Decode `reqs` through `slots` KV slots; returns (secs, sequence-tokens).
fn run_batched(
    be: &NativeBackend,
    reqs: &[(Vec<u8>, usize)],
    slots: usize,
    capacity: usize,
    kv: KvBits,
) -> (f64, usize) {
    let t0 = Instant::now();
    let cfg = EngineConfig::new()
        .with_max_batch(slots)
        .with_max_context(capacity)
        .with_kv_bits(kv);
    let mut dec = BatchDecoder::with_config(be, &cfg).expect("batch decoder");
    for (i, (prompt, gen)) in reqs.iter().enumerate() {
        dec.submit(i, prompt, *gen).expect("submit");
    }
    dec.run().expect("batched decode");
    (t0.elapsed().as_secs_f64(), dec.stats().tokens)
}

/// Decode `reqs` one sequence at a time through `NativeDecoder`.
fn run_single(
    be: &NativeBackend,
    reqs: &[(Vec<u8>, usize)],
    capacity: usize,
    kv: KvBits,
) -> (f64, usize) {
    let t0 = Instant::now();
    let mut tokens = 0usize;
    let cfg = EngineConfig::new().with_max_context(capacity).with_kv_bits(kv);
    for (prompt, gen) in reqs {
        let mut dec = NativeDecoder::with_config(be, &cfg).expect("decoder");
        dec.generate(prompt, *gen).expect("single decode");
        tokens += prompt.len() + gen - 1;
    }
    (t0.elapsed().as_secs_f64(), tokens)
}

/// Best-of-`reps` wall clock for one batch size (damps scheduler noise).
fn best_of(
    reps: usize,
    be: &NativeBackend,
    reqs: &[(Vec<u8>, usize)],
    batch: usize,
    capacity: usize,
    kv: KvBits,
) -> (f64, usize) {
    let mut best_secs = f64::INFINITY;
    let mut tokens = 0usize;
    for _ in 0..reps {
        let (secs, toks) = if batch == 1 {
            run_single(be, reqs, capacity, kv)
        } else {
            run_batched(be, reqs, batch, capacity, kv)
        };
        best_secs = best_secs.min(secs);
        tokens = toks;
    }
    (best_secs, tokens)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok() || std::env::args().any(|a| a == "--quick");
    let (n_req, prompt_len, gen, reps) = if quick { (16, 8, 12, 1) } else { (32, 16, 48, 3) };

    simd::force(None);
    let kernel = simd::kernel_name().to_string();

    let mw = load_or_synthetic("artifacts", "tiny", 2026);
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).expect("quantize");
    let be = NativeBackend::from_quantized(&qm);
    let corpus = Corpus::load_or_synthetic("artifacts", "wiki", "eval");
    let reqs: Vec<(Vec<u8>, usize)> = (0..n_req)
        .map(|i| (corpus.data[i * prompt_len..(i + 1) * prompt_len].to_vec(), gen))
        .collect();
    let capacity = prompt_len + gen + 1;

    // Parity gates: the batched engine must reproduce single-sequence
    // greedy tokens exactly, and the SIMD kernels must emit the same
    // tokens as the scalar fallback, before throughput means anything.
    {
        let mut dec = BatchDecoder::new(&be, 4, capacity).expect("batch decoder");
        for (i, (prompt, g)) in reqs.iter().take(6).enumerate() {
            dec.submit(i, prompt, *g).expect("submit");
        }
        for out in dec.run().expect("batched decode") {
            let (prompt, g) = &reqs[out.id];
            let mut single = NativeDecoder::new(&be, capacity).expect("decoder");
            let want = single.generate(prompt, *g).expect("single decode");
            assert_eq!(out.tokens, want, "batched decode diverged on request {}", out.id);

            simd::force(Some(Isa::Scalar));
            let mut scalar = NativeDecoder::new(&be, capacity).expect("decoder");
            let scalar_tokens = scalar.generate(prompt, *g).expect("scalar decode");
            simd::force(None);
            assert_eq!(
                out.tokens, scalar_tokens,
                "scalar and {kernel} kernels disagree on request {}",
                out.id
            );
        }
    }

    println!(
        "decode bench: tiny/sinq-4b, {n_req} requests, prompt {prompt_len}, +{gen}, \
         kernel '{kernel}'\n"
    );
    let mut summary: Vec<Json> = Vec::new();
    let mut tps_batch1 = 0.0f64;
    for batch in [1usize, 4, 16] {
        simd::force(None);
        let (simd_secs, tokens) = best_of(reps, &be, &reqs, batch, capacity, KvBits::F32);
        let (kv8_secs, _) = best_of(reps, &be, &reqs, batch, capacity, KvBits::Q8);
        simd::force(Some(Isa::Scalar));
        let (scalar_secs, _) = best_of(reps, &be, &reqs, batch, capacity, KvBits::F32);
        simd::force(None);

        let tps = tokens as f64 / simd_secs;
        let tps_scalar = tokens as f64 / scalar_secs;
        let tps_kv8 = tokens as f64 / kv8_secs;
        let simd_speedup = tps / tps_scalar;
        if batch == 1 {
            tps_batch1 = tps;
        }
        let speedup = tps / tps_batch1;
        println!(
            "batch {batch:>2}: {tokens} sequence-tokens in {simd_secs:.3}s \
             → {tps:.0} tok/s ({speedup:.2}x vs batch 1); scalar {tps_scalar:.0} tok/s \
             → {simd_speedup:.2}x from '{kernel}'; kv8 {tps_kv8:.0} tok/s"
        );
        summary.push(Json::obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("tokens", Json::Num(tokens as f64)),
            ("secs", Json::Num(simd_secs)),
            ("tokens_per_sec", Json::Num(tps)),
            ("speedup", Json::Num(speedup)),
            ("secs_scalar", Json::Num(scalar_secs)),
            ("tokens_per_sec_scalar", Json::Num(tps_scalar)),
            ("simd_speedup", Json::Num(simd_speedup)),
            ("secs_kv8", Json::Num(kv8_secs)),
            ("tokens_per_sec_kv8", Json::Num(tps_kv8)),
        ]));
    }

    // Thread scaling: decode batch 16 with the engine pinned to one worker
    // and again at the resolved auto width. The persistent pool partitions
    // fused-matmul row tiles and attention heads, never the within-row
    // summation order, so tokens must be bit-identical at every width —
    // asserted here before the ratio is recorded. Under a `SINQ_THREADS`
    // CI leg the env override pins both runs to the same width, and on a
    // single-core runner auto == 1, so the ratio degenerates to ~1.0 in
    // both cases (which is why the check_bench gate is opt-in).
    let threads_auto = EngineConfig::new().effective_threads();
    let run_threads = |threads: usize| {
        let cfg = EngineConfig::new()
            .with_max_batch(16)
            .with_max_context(capacity)
            .with_threads(threads);
        let mut best = f64::INFINITY;
        let mut tokens = 0usize;
        let mut outs: Vec<Vec<u8>> = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            let mut dec = BatchDecoder::with_config(&be, &cfg).expect("batch decoder");
            for (i, (prompt, g)) in reqs.iter().enumerate() {
                dec.submit(i, prompt, *g).expect("submit");
            }
            let got = dec.run().expect("decode");
            best = best.min(t0.elapsed().as_secs_f64());
            tokens = dec.stats().tokens;
            outs = got.into_iter().map(|o| o.tokens).collect();
        }
        (best, tokens, outs)
    };
    let (t1_secs, scale_tokens, toks_t1) = run_threads(1);
    let (tn_secs, _, toks_tn) = run_threads(0);
    assert_eq!(toks_t1, toks_tn, "thread count changed decoded tokens");
    let tokens_per_sec_t1 = scale_tokens as f64 / t1_secs;
    let tokens_per_sec_tn = scale_tokens as f64 / tn_secs;
    let thread_scaling = tokens_per_sec_tn / tokens_per_sec_t1;
    println!(
        "threads: 1 worker → {tokens_per_sec_t1:.0} tok/s, {threads_auto} (auto) → \
         {tokens_per_sec_tn:.0} tok/s → {thread_scaling:.2}x scaling; tokens bit-identical"
    );

    // Profiling overhead: the per-phase timers in the decode core must be
    // ~free when disabled (one branch per phase) and cheap enough when
    // enabled that opting into SINQ_PROFILE does not distort what it
    // measures. Gated ≤ 3% by scripts/check_bench.sh. Tokens must be
    // bit-identical either way.
    profiler::set_enabled(true);
    let mut profiled = NativeDecoder::new(&be, capacity).expect("decoder");
    let profiled_tokens = profiled.generate(&reqs[0].0, reqs[0].1).expect("profiled decode");
    profiler::set_enabled(false);
    let mut plain = NativeDecoder::new(&be, capacity).expect("decoder");
    let plain_tokens = plain.generate(&reqs[0].0, reqs[0].1).expect("decode");
    assert_eq!(profiled_tokens, plain_tokens, "profiling changed decoded tokens");

    // Best-of-N both ways damps scheduler noise below the 3% gate.
    let preps = reps.max(2);
    let (off_secs, prof_tokens) = best_of(preps, &be, &reqs, 16, capacity, KvBits::F32);
    profiler::set_enabled(true);
    profiler::reset();
    let (on_secs, _) = best_of(preps, &be, &reqs, 16, capacity, KvBits::F32);
    let phase_snapshot = profiler::snapshot();
    profiler::set_enabled(false);
    let tps_off = prof_tokens as f64 / off_secs;
    let tps_on = prof_tokens as f64 / on_secs;
    let profiling_overhead_pct = ((tps_off - tps_on) / tps_off * 100.0).max(0.0);
    let hottest = phase_snapshot
        .phases
        .first()
        .map(|p| format!("{} {:.1}%", p.phase, p.pct))
        .unwrap_or_else(|| "none".to_string());
    println!(
        "profiler: off {tps_off:.0} tok/s, on {tps_on:.0} tok/s \
         → {profiling_overhead_pct:.2}% overhead; hottest phase {hottest}"
    );

    // Flight-recorder costs. The drift sentinel at its documented default
    // rate (1-in-16 steps) recomputes one live row through the scalar
    // kernels per sampled step; that must cost ≤ 3% batched throughput
    // (gated by scripts/check_bench.sh) and must never perturb decode.
    // The event journal likewise must leave tokens bit-identical.
    let run_flight = |drift_sample: usize| {
        let cfg = EngineConfig::new()
            .with_max_batch(16)
            .with_max_context(capacity)
            .with_drift_sample(drift_sample);
        let mut best = f64::INFINITY;
        let mut tokens = 0usize;
        let mut outs: Vec<Vec<u8>> = Vec::new();
        for _ in 0..preps {
            let t0 = Instant::now();
            let mut dec = BatchDecoder::with_config(&be, &cfg).expect("batch decoder");
            for (i, (prompt, g)) in reqs.iter().enumerate() {
                dec.submit(i, prompt, *g).expect("submit");
            }
            let got = dec.run().expect("decode");
            best = best.min(t0.elapsed().as_secs_f64());
            tokens = dec.stats().tokens;
            outs = got.into_iter().map(|o| o.tokens).collect();
        }
        (best, tokens, outs)
    };
    let (drift_off_secs, flight_tokens, toks_plain) = run_flight(0);
    drift::reset();
    let (drift_on_secs, _, toks_sentinel) = run_flight(16);
    let drift_snap = drift::snapshot();
    drift::reset();
    assert_eq!(toks_sentinel, toks_plain, "drift sentinel changed decoded tokens");
    assert!(drift_snap.samples > 0, "sentinel sampled nothing at 1-in-16");
    let tps_drift_off = flight_tokens as f64 / drift_off_secs;
    let tps_drift_on = flight_tokens as f64 / drift_on_secs;
    let drift_overhead_pct = ((tps_drift_off - tps_drift_on) / tps_drift_off * 100.0).max(0.0);
    println!(
        "drift sentinel (1-in-16): off {tps_drift_off:.0} tok/s, on {tps_drift_on:.0} tok/s \
         → {drift_overhead_pct:.2}% overhead; {} samples, {} argmax flips, \
         max |Δ| {:.2e}",
        drift_snap.samples, drift_snap.argmax_flips, drift_snap.max_abs_diff
    );

    journal::reset();
    journal::set_enabled(true);
    let (_, _, toks_journaled) = run_flight(0);
    journal::set_enabled(false);
    let journal_events = journal::snapshot(usize::MAX).len();
    let journal_tokens_identical = toks_journaled == toks_plain;
    assert!(journal_tokens_identical, "event journal changed decoded tokens");
    println!("journal: {journal_events} events recorded, tokens bit-identical with recorder off");

    // Per-slot KV memory at both precisions (what --max-batch multiplies).
    let slot_cfg = EngineConfig::new().with_max_context(capacity);
    let kv_bytes_f32 = NativeDecoder::with_config(&be, &slot_cfg.with_kv_bits(KvBits::F32))
        .expect("decoder")
        .kv_bytes();
    let kv_bytes_q8 = NativeDecoder::with_config(&be, &slot_cfg.with_kv_bits(KvBits::Q8))
        .expect("decoder")
        .kv_bytes();
    let kv_reduction = kv_bytes_f32 as f64 / kv_bytes_q8 as f64;
    println!(
        "kv cache per slot ({capacity} positions): f32 {kv_bytes_f32}B, \
         q8 {kv_bytes_q8}B → {kv_reduction:.2}x smaller"
    );

    // Supervised engine: catch_unwind panic isolation, the exactly-once
    // terminal roster, and per-request deadline checks must not tax the
    // decode path. With every fault point disarmed, tokens through the
    // supervised GenEngine must be bit-identical to the bare BatchDecoder
    // and the throughput gap ≤ 3% (gated by scripts/check_bench.sh).
    let be = Arc::new(be);
    let eng = GenEngine::start(
        be.clone(),
        EngineConfig::new().with_max_batch(16).with_max_context(capacity),
        n_req,
        Arc::new(ServeMetrics::new()),
    )
    .expect("supervised engine");
    let client = eng.client();
    let mut supervised_secs = f64::INFINITY;
    let mut toks_supervised: Vec<Vec<u8>> = Vec::new();
    for _ in 0..preps {
        let t0 = Instant::now();
        let handles: Vec<_> = reqs
            .iter()
            .map(|(p, g)| client.submit(p.clone(), *g, None, None).expect("submit"))
            .collect();
        let mut toks: Vec<Vec<u8>> = Vec::new();
        for h in handles {
            let mut seq = Vec::new();
            for ev in h.rx.iter() {
                match ev {
                    StreamEvent::Token(t) => seq.push(t),
                    StreamEvent::Done { .. } => {}
                    StreamEvent::Failed { message, .. } => {
                        panic!("supervised decode failed: {message}")
                    }
                }
            }
            toks.push(seq);
        }
        supervised_secs = supervised_secs.min(t0.elapsed().as_secs_f64());
        toks_supervised = toks;
    }
    eng.shutdown();
    let supervised_tokens_identical = toks_supervised == toks_plain;
    assert!(supervised_tokens_identical, "supervision changed decoded tokens");
    let tps_supervised = flight_tokens as f64 / supervised_secs;
    let supervised_overhead_pct =
        ((tps_drift_off - tps_supervised) / tps_drift_off * 100.0).max(0.0);
    println!(
        "supervised engine (faults disarmed): bare {tps_drift_off:.0} tok/s, \
         supervised {tps_supervised:.0} tok/s → {supervised_overhead_pct:.2}% overhead; \
         tokens bit-identical"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("decode".to_string())),
        ("model", Json::Str("tiny".to_string())),
        ("method", Json::Str("sinq".to_string())),
        ("bits", Json::Num(4.0)),
        ("kernel", Json::Str(kernel)),
        ("requests", Json::Num(n_req as f64)),
        ("prompt_len", Json::Num(prompt_len as f64)),
        ("gen_tokens", Json::Num(gen as f64)),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(threads_auto as f64)),
        ("tokens_per_sec_t1", Json::Num(tokens_per_sec_t1)),
        ("tokens_per_sec_tN", Json::Num(tokens_per_sec_tn)),
        ("thread_scaling", Json::Num(thread_scaling)),
        ("kv_bytes_per_slot_f32", Json::Num(kv_bytes_f32 as f64)),
        ("kv_bytes_per_slot_q8", Json::Num(kv_bytes_q8 as f64)),
        ("kv_reduction", Json::Num(kv_reduction)),
        ("profiling_overhead_pct", Json::Num(profiling_overhead_pct)),
        ("drift_overhead_pct", Json::Num(drift_overhead_pct)),
        ("drift_samples", Json::Num(drift_snap.samples as f64)),
        ("drift_argmax_flips", Json::Num(drift_snap.argmax_flips as f64)),
        ("journal_tokens_identical", Json::Bool(journal_tokens_identical)),
        ("supervised_tokens_identical", Json::Bool(supervised_tokens_identical)),
        ("supervised_overhead_pct", Json::Num(supervised_overhead_pct)),
        ("tokens_per_sec_supervised", Json::Num(tps_supervised)),
        ("results", Json::Arr(summary)),
    ]);
    // Repo root, resolved from the package dir so cwd does not matter.
    let out = format!("{}/../BENCH_decode.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&out, report.to_string_compact()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
