//! Micro-benchmarks of the L3 hot paths (the §Perf baseline numbers):
//! matmul kernels, FWHT, Sinkhorn normalization, grouped RTN, packing.
//!
//! Run with `cargo bench --bench micro` (hand-rolled harness; criterion is
//! unavailable offline).

use sinq::fmt::pack;
use sinq::quant::hadamard::fwht;
use sinq::quant::rtn;
use sinq::quant::sinq::sinkhorn_normalize;
use sinq::fmt::grids::Grid;
use sinq::tensor::{Matrix, Rng};
use sinq::util::bench::Bencher;
use std::hint::black_box;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(1);

    // matmul_nt: the reference-forward workhorse (x · Wᵀ).
    let x = Matrix::randn(128, 256, 1.0, &mut rng);
    let w = Matrix::randn(512, 256, 1.0, &mut rng);
    let s = b.bench("matmul_nt 128x256 · (512x256)ᵀ", || {
        black_box(x.matmul_nt(&w));
    });
    let flops = 2.0 * 128.0 * 256.0 * 512.0;
    println!("    -> {:.2} GFLOP/s", flops / s.mean_ns);

    let a = Matrix::randn(128, 256, 1.0, &mut rng);
    let c = Matrix::randn(256, 512, 1.0, &mut rng);
    let s = b.bench("matmul    128x256 · 256x512", || {
        black_box(a.matmul(&c));
    });
    println!("    -> {:.2} GFLOP/s", flops / s.mean_ns);

    // FWHT over a model-sized rotation (1024-dim, 512 rows).
    let mut m = Matrix::randn(512, 1024, 1.0, &mut rng);
    let s = b.bench("fwht rotate_cols 512x1024", || {
        for i in 0..m.rows {
            fwht(m.row_mut(i));
        }
        black_box(&m);
    });
    let elems = 512.0 * 1024.0;
    println!("    -> {:.1} Melem/s", elems / s.mean_ns * 1e3);

    // Sinkhorn normalization (Algorithm 1's loop) on an ffn-sized layer.
    let w = Matrix::randn(1024, 256, 0.02, &mut rng);
    let s = b.bench("sinkhorn_normalize 1024x256 K=24", || {
        black_box(sinkhorn_normalize(&w, 24, (0.5, 2.0)));
    });
    println!("    -> {:.1} Melem/s·iter", elems / 4.0 * 24.0 / s.mean_ns * 1e3);

    // Grouped RTN (line 18 of Algorithm 1).
    let grid = Grid::uniform(4);
    let s = b.bench("rtn quantize_grouped 1024x256 g=64", || {
        black_box(rtn::quantize_grouped(&w, &grid, 64, true));
    });
    println!("    -> {:.1} Melem/s", (1024.0 * 256.0) / s.mean_ns * 1e3);

    // Bit packing.
    let codes: Vec<u8> = (0..1024 * 256).map(|i| (i % 16) as u8).collect();
    let s = b.bench("pack int4 262144 codes", || {
        black_box(pack::pack(&codes, 4));
    });
    println!("    -> {:.1} Melem/s", codes.len() as f64 / s.mean_ns * 1e3);

    let _ = b.dump_jsonl("artifacts/bench_micro.jsonl");
}
