//! Table 10 / Fig. 8 — quantization wall time per method, on the trained
//! family when artifacts exist (synthetic fallback otherwise).
//!
//! `cargo bench --bench quantizers`

use sinq::coordinator::pipeline::{self, PipelineOpts};
use sinq::coordinator::scheduler::{load_or_synthetic, ScheduleOpts};
use sinq::quant::{Method, QuantConfig};
use sinq::util::bench::Bencher;
use std::hint::black_box;

fn main() {
    let mut b = Bencher::quick();
    for model in ["pico", "tiny"] {
        let mw = load_or_synthetic("artifacts", model, 99);
        let calib: Vec<u8> = b"calibration sample text for activation capture. ".repeat(16).to_vec();
        let params: usize = mw.cfg.n_params();
        for method in
            [Method::Rtn, Method::Hqq, Method::Sinq, Method::Awq, Method::Gptq, Method::ASinq]
        {
            let cfg = QuantConfig::new(method, 4);
            let opts = PipelineOpts {
                schedule: ScheduleOpts {
                    threads: 1,
                    calib_sample: method.needs_calibration().then(|| calib.clone()),
                    verbose: false,
                },
                no_overhead: false,
            };
            let s = b.bench(&format!("quantize {model} {}", method.name()), || {
                black_box(pipeline::run(&mw, &cfg, &opts).unwrap());
            });
            println!(
                "    -> {:.1} Mparam/s",
                params as f64 / s.mean_ns * 1e3
            );
        }
    }
    let _ = b.dump_jsonl("artifacts/bench_quantizers.jsonl");
}
