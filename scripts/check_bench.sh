#!/usr/bin/env bash
# Validate the BENCH_*.json summaries emitted by `cargo bench --bench
# backend` / `--bench decode` / `--bench serve` before CI archives them:
# each file must be well-formed JSON with a named bench and a non-empty
# `results` array of finite numbers. The decode report must additionally
# carry per-batch throughput (the ≥8-batch row is the amortization
# headline), the scalar-vs-SIMD fields (`tokens_per_sec_scalar`,
# `simd_speedup`, top-level `kernel`), and the KV-cache fields
# (`tokens_per_sec_kv8` per row; top-level `kv_bytes_per_slot_f32/q8`
# with `kv_reduction` ≥ 3x), a `profiling_overhead_pct` ≤ 3 (the
# per-phase decode timers must stay near-free), a `drift_overhead_pct`
# ≤ 3 with `drift_samples` > 0 (the numerical drift sentinel at its
# 1-in-16 default must be near-free), `journal_tokens_identical`
# (the flight-recorder journal must not perturb decode), and
# `supervised_tokens_identical` with `supervised_overhead_pct` ≤ 3 (the
# supervised engine — panic isolation + terminal roster + deadline
# checks — must be bit-exact and near-free with faults disarmed); the
# serve report needs
# per-concurrency requests/sec plus a median TTFT, and the shared-prefix
# fields (`prefix_tokens`, `ttft_cold_prefix_ms`, `ttft_hit_prefix_ms`).
# Fails loudly so a silently-broken bench cannot upload garbage artifacts.
#
# Set CHECK_BENCH_SIMD_SPEEDUP=<x> (e.g. 1.5) to additionally require the
# decode report's SIMD path to be ≥ x× scalar tokens/sec at batch 1 and
# 16 — CI's bench-smoke sets this on runners whose dispatcher selects a
# non-scalar kernel, so the SIMD paths cannot silently regress to parity
# with the fallback. Set CHECK_BENCH_THREAD_SCALING=<x> (e.g. 1.3) to
# additionally require the decode report's auto-width worker pool to be
# ≥ x× the single-thread tokens/sec at batch 16 — CI's bench-smoke sets
# this on multi-core runners without a SINQ_THREADS pin, so the pool
# cannot silently regress to serial throughput (skipped automatically
# when the report shows only one resolved worker, where the ratio is
# ~1.0 by construction). Set CHECK_BENCH_PREFIX_TTFT=1 to additionally require
# the serve report's prefix-hit TTFT to beat its cold TTFT (the prefix
# cache must actually skip prefill; off by default because quick-mode
# wall-clocks are noisy).
set -euo pipefail

if [ "$#" -eq 0 ]; then
  echo "usage: $0 BENCH_backend.json [BENCH_decode.json BENCH_serve.json ...]" >&2
  exit 2
fi

for f in "$@"; do
  if [ ! -f "$f" ]; then
    echo "check_bench: missing $f" >&2
    exit 1
  fi
  python3 - "$f" <<'PYEOF'
import json
import math
import sys

path = sys.argv[1]
with open(path) as fh:
    doc = json.load(fh)

bench = doc.get("bench")
assert isinstance(bench, str) and bench, f"{path}: missing 'bench' name"
results = doc.get("results")
assert isinstance(results, list) and results, f"{path}: empty or missing 'results'"

for row in results:
    assert isinstance(row, dict), f"{path}: non-object result row {row!r}"
    nums = {k: v for k, v in row.items() if isinstance(v, (int, float))}
    assert nums, f"{path}: result row has no numeric fields: {row!r}"
    for key, val in nums.items():
        assert math.isfinite(val), f"{path}: non-finite '{key}' in {row!r}"

if bench == "decode":
    import os

    kernel = doc.get("kernel")
    assert isinstance(kernel, str) and kernel, f"{path}: missing dispatched 'kernel' name"
    batches = []
    for row in results:
        assert row.get("tokens_per_sec", 0) > 0, f"{path}: zero throughput row {row!r}"
        assert row.get("tokens_per_sec_scalar", 0) > 0, f"{path}: zero scalar row {row!r}"
        assert row.get("simd_speedup", 0) > 0, f"{path}: missing simd_speedup in {row!r}"
        assert row.get("tokens_per_sec_kv8", 0) > 0, f"{path}: missing kv8 throughput in {row!r}"
        batches.append(row.get("batch", 0))
    assert any(b >= 8 for b in batches), f"{path}: no batch ≥ 8 row (got {batches})"
    assert any(b == 1 for b in batches), f"{path}: no batch-1 baseline row"
    kv_f32 = doc.get("kv_bytes_per_slot_f32", 0)
    kv_q8 = doc.get("kv_bytes_per_slot_q8", 0)
    assert kv_f32 > 0 and kv_q8 > 0, f"{path}: missing per-slot KV byte fields"
    kv_red = doc.get("kv_reduction", 0)
    assert kv_red >= 3.0, (
        f"{path}: kv8 slot only {kv_red:.2f}x smaller than f32 (gate: ≥ 3x)"
    )
    overhead = doc.get("profiling_overhead_pct")
    assert isinstance(overhead, (int, float)) and math.isfinite(overhead), (
        f"{path}: missing 'profiling_overhead_pct'"
    )
    assert overhead <= 3.0, (
        f"{path}: per-phase profiling costs {overhead:.2f}% throughput (gate: ≤ 3%)"
    )
    drift = doc.get("drift_overhead_pct")
    assert isinstance(drift, (int, float)) and math.isfinite(drift), (
        f"{path}: missing 'drift_overhead_pct'"
    )
    assert drift <= 3.0, (
        f"{path}: drift sentinel at 1-in-16 costs {drift:.2f}% throughput (gate: ≤ 3%)"
    )
    assert doc.get("drift_samples", 0) > 0, f"{path}: drift sentinel recorded no samples"
    assert doc.get("journal_tokens_identical") is True, (
        f"{path}: decode tokens changed with the event journal on"
    )
    assert doc.get("supervised_tokens_identical") is True, (
        f"{path}: decode tokens changed under the supervised engine"
    )
    supervised = doc.get("supervised_overhead_pct")
    assert isinstance(supervised, (int, float)) and math.isfinite(supervised), (
        f"{path}: missing 'supervised_overhead_pct'"
    )
    assert supervised <= 3.0, (
        f"{path}: engine supervision costs {supervised:.2f}% throughput (gate: ≤ 3%)"
    )
    want = os.environ.get("CHECK_BENCH_SIMD_SPEEDUP", "")
    if want and kernel != "scalar":
        need = float(want)
        for target in (1, 16):
            row = next((r for r in results if r.get("batch") == target), None)
            assert row is not None, f"{path}: no batch-{target} row for the SIMD gate"
            got = row["simd_speedup"]
            assert got >= need, (
                f"{path}: batch {target} SIMD speedup {got:.2f}x < required {need}x "
                f"(kernel '{kernel}')"
            )
        print(f"check_bench: {path} SIMD gate ok (kernel '{kernel}', ≥{need}x)")
    threads = doc.get("threads", 0)
    assert threads >= 1, f"{path}: missing resolved 'threads' count"
    tps_t1 = doc.get("tokens_per_sec_t1", 0)
    tps_tn = doc.get("tokens_per_sec_tN", 0)
    scaling = doc.get("thread_scaling", 0)
    assert tps_t1 > 0, f"{path}: missing 'tokens_per_sec_t1'"
    assert tps_tn > 0, f"{path}: missing 'tokens_per_sec_tN'"
    assert isinstance(scaling, (int, float)) and math.isfinite(scaling) and scaling > 0, (
        f"{path}: missing 'thread_scaling'"
    )
    want_scaling = os.environ.get("CHECK_BENCH_THREAD_SCALING", "")
    if want_scaling and threads > 1 and not os.environ.get("SINQ_THREADS", ""):
        need = float(want_scaling)
        assert scaling >= need, (
            f"{path}: thread scaling {scaling:.2f}x at batch 16 "
            f"({tps_t1:.0f} → {tps_tn:.0f} tok/s over {threads:.0f} workers) "
            f"< required {need}x"
        )
        print(
            f"check_bench: {path} thread gate ok "
            f"({threads:.0f} workers, ≥{need}x, got {scaling:.2f}x)"
        )

if bench == "serve":
    import os

    batches = []
    for row in results:
        assert row.get("requests_per_sec", 0) > 0, f"{path}: zero req/s row {row!r}"
        assert row.get("ttft_median_ms", -1) >= 0, f"{path}: missing TTFT in {row!r}"
        batches.append(row.get("batch", 0))
    assert any(b >= 16 for b in batches), f"{path}: no concurrency ≥ 16 row (got {batches})"
    assert any(b == 1 for b in batches), f"{path}: no concurrency-1 baseline row"
    prefix_tokens = doc.get("prefix_tokens", 0)
    assert prefix_tokens >= 512, f"{path}: shared-prefix phase missing (got {prefix_tokens})"
    cold = doc.get("ttft_cold_prefix_ms", 0)
    hit = doc.get("ttft_hit_prefix_ms", 0)
    assert cold > 0 and hit > 0, f"{path}: missing shared-prefix TTFT fields"
    if os.environ.get("CHECK_BENCH_PREFIX_TTFT", ""):
        assert hit < cold, (
            f"{path}: prefix-hit TTFT {hit:.1f}ms not below cold {cold:.1f}ms — "
            f"the prefix cache is not skipping prefill"
        )
        print(f"check_bench: {path} prefix gate ok (cold {cold:.1f}ms → hit {hit:.1f}ms)")

print(f"check_bench: {path} ok ({bench}, {len(results)} rows)")
PYEOF
done
